"""Fail on broken relative links — and broken anchors — in the repo's
markdown docs.

Checks every ``[text](target)`` in README.md and docs/*.md:

- relative-path targets must exist on disk (external URLs and
  ``mailto:`` are skipped), resolved against the linking file's
  directory;
- ``#section`` suffixes (and pure ``#anchor`` links) must match a
  heading in the target file, using GitHub's slug rules (lowercase,
  punctuation stripped, spaces → hyphens).

Run from the repo root:

  python tools/check_links.py
"""

from __future__ import annotations

import glob
import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
FILES = ["README.md", *sorted(glob.glob("docs/*.md"))]


def _strip_code(text: str) -> str:
    # fenced code blocks aren't links or headings
    return re.sub(r"```.*?```", "", text, flags=re.S)


def _slug(heading: str) -> str:
    """GitHub anchor slug: inline code/formatting dropped, lowercase,
    keep word chars/spaces/hyphens, spaces → hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: str, cache: dict[str, set]) -> set[str]:
    if path not in cache:
        text = _strip_code(open(path).read())
        cache[path] = {_slug(m) for m in HEADING.findall(text)}
    return cache[path]


def check(paths=FILES) -> list[str]:
    errors = []
    anchor_cache: dict[str, set] = {}
    for md in paths:
        if not os.path.exists(md):
            errors.append(f"{md}: file listed for checking is missing")
            continue
        text = _strip_code(open(md).read())
        for target in LINK.findall(text):
            if "://" in target or target.startswith("mailto:"):
                continue
            rel, _, frag = target.partition("#")
            resolved = (os.path.normpath(os.path.join(
                os.path.dirname(md), rel)) if rel else md)
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken link -> {target}")
                continue
            if frag and resolved.endswith(".md"):
                # compare the fragment verbatim: GitHub ids are
                # lowercase slugs, so an uppercase fragment is broken
                # even when it lowercases to a real heading
                if frag not in _anchors(resolved, anchor_cache):
                    errors.append(f"{md}: broken anchor -> {target}")
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(FILES)} files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
