"""Fail on broken relative links in the repo's markdown docs.

Checks every ``[text](target)`` whose target is a relative path
(external URLs and pure ``#anchor`` links are skipped) in README.md
and docs/*.md; targets are resolved against the linking file's
directory, ``#section`` suffixes stripped.  Run from the repo root:

  python tools/check_links.py
"""

from __future__ import annotations

import glob
import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FILES = ["README.md", *sorted(glob.glob("docs/*.md"))]


def check(paths=FILES) -> list[str]:
    errors = []
    for md in paths:
        if not os.path.exists(md):
            errors.append(f"{md}: file listed for checking is missing")
            continue
        text = open(md).read()
        # strip fenced code blocks — snippets aren't links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in LINK.findall(text):
            if "://" in target or target.startswith(("#", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), rel))
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken link -> {target}")
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(FILES)} files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
