"""Validate the observability artifacts the serving CLIs emit
(docs/observability.md) — CI runs this over the smoke-serve outputs.

  python tools/check_trace.py --trace trace.json --metrics metrics.json

Trace file: a Chrome-trace-event JSON array (the format Perfetto /
chrome://tracing load).  Checked per event: required keys, known
phase, integer microsecond timestamps, non-negative durations on
complete events.  The file must contain at least one ``engine.step``
span when ``--require-span`` names are given.

Metrics file: a ``Registry.snapshot()`` JSON dump.  Checked: valid
strict JSON (``NaN``/``Infinity`` literals are rejected — the
Scheduler.summary NaN regression this PR fixed), known metric kinds,
histogram count == sum of bucket counts, and any ``--require-metric``
names present.
"""

from __future__ import annotations

import argparse
import json
import sys

KINDS = {"counter", "gauge", "histogram"}
PHASES = {"X", "i", "B", "E", "M"}


def fail(msg: str) -> None:
    print(f"check_trace: FAIL: {msg}")
    sys.exit(1)


def check_trace(path: str, require_spans: list[str]) -> int:
    # strict: the JSON spec has no NaN/Infinity literals
    text = open(path).read()
    events = json.loads(text, parse_constant=lambda c: fail(
        f"{path}: non-standard JSON constant {c!r}"))
    if not isinstance(events, list):
        fail(f"{path}: top level must be a JSON array of events")
    if not events:
        fail(f"{path}: empty trace — no spans were recorded")
    names = set()
    for i, ev in enumerate(events):
        missing = {"name", "ph", "ts", "pid", "tid"} - set(ev)
        if missing:
            fail(f"{path}: event {i} missing keys {sorted(missing)}")
        if ev["ph"] not in PHASES:
            fail(f"{path}: event {i} unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], int) or ev["ts"] < 0:
            fail(f"{path}: event {i} ts must be a non-negative int "
                 f"(microseconds)")
        if ev["ph"] == "X" and (not isinstance(ev.get("dur"), int)
                                or ev["dur"] < 0):
            fail(f"{path}: complete event {i} needs int dur >= 0")
        names.add(ev["name"])
    for want in require_spans:
        if want not in names:
            fail(f"{path}: required span {want!r} absent "
                 f"(got {sorted(names)[:12]}...)")
    print(f"check_trace: {path}: {len(events)} events, "
          f"{len(names)} distinct span names OK")
    return len(events)


def check_metrics(path: str, require_metrics: list[str]) -> int:
    text = open(path).read()
    snap = json.loads(text, parse_constant=lambda c: fail(
        f"{path}: non-standard JSON constant {c!r}"))
    if not isinstance(snap, dict) or not snap:
        fail(f"{path}: expected a non-empty snapshot object")
    for name, m in snap.items():
        if m.get("kind") not in KINDS:
            fail(f"{path}: metric {name!r} has unknown kind "
                 f"{m.get('kind')!r}")
        series = m.get("series")
        if not isinstance(series, dict):
            fail(f"{path}: metric {name!r} missing series map")
        for labels, s in series.items():
            if m["kind"] == "histogram":
                if sum(s["counts"]) != s["count"]:
                    fail(f"{path}: {name}{labels}: bucket counts "
                         f"{sum(s['counts'])} != count {s['count']}")
                if len(s["counts"]) != len(s["buckets"]) + 1:
                    fail(f"{path}: {name}{labels}: needs one overflow "
                         f"bucket beyond the boundaries")
            elif not (s is None or isinstance(s, (int, float))):
                fail(f"{path}: {name}{labels}: scalar series must be "
                     f"a number or null, got {type(s).__name__}")
    for want in require_metrics:
        if want not in snap:
            fail(f"{path}: required metric {want!r} absent "
                 f"(got {sorted(snap)[:12]}...)")
    print(f"check_trace: {path}: {len(snap)} metrics OK")
    return len(snap)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None,
                    help="Chrome-trace JSON file to validate")
    ap.add_argument("--metrics", default=None,
                    help="Registry.snapshot() JSON file to validate")
    ap.add_argument("--require-span", action="append", default=[],
                    help="span name that must appear in the trace "
                         "(repeatable)")
    ap.add_argument("--require-metric", action="append", default=[],
                    help="metric name that must appear in the "
                         "snapshot (repeatable)")
    args = ap.parse_args()
    if not args.trace and not args.metrics:
        fail("nothing to check: pass --trace and/or --metrics")
    if args.trace:
        check_trace(args.trace, args.require_span)
    if args.metrics:
        check_metrics(args.metrics, args.require_metric)


if __name__ == "__main__":
    main()
