"""Deterministic synthetic token pipeline.

Stateless by construction — ``batch_for_step(step)`` is a pure function
of (seed, step), so a restart resumes the exact data order with no
pipeline checkpointing (the fault-tolerance contract in DESIGN.md §4).
Batches are produced already sharded across the mesh's batch axes via
jax.make_array_from_callback, so no host gathers the global batch.

The generator mimics LM token statistics (Zipfian unigrams with a
Markov-ish repetition structure) so that tiny-model CE losses behave
like real text rather than uniform noise.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import named_sharding


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.3     # probability of copying an earlier token


def _zipf_logits(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return np.log(p / p.sum())


class SyntheticLM:
    """step -> {"tokens", "labels"} with tokens[t+1] == labels[t]."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._logits = jnp.asarray(_zipf_logits(cfg.vocab, cfg.zipf_a),
                                   jnp.float32)

    def _sample(self, key, batch: int):
        c = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        base = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (batch, c.seq_len + 1,
                                                c.vocab)))
        # repetition structure: with prob repeat_p copy the token 8 back
        rep = jax.random.bernoulli(k2, c.repeat_p, (batch, c.seq_len + 1))
        shifted = jnp.roll(base, 8, axis=1)
        toks = jnp.where(rep, shifted, base)
        return toks

    def batch_for_step(self, step: int, mesh=None):
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        if mesh is None:
            toks = self._sample(key, c.global_batch)
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        sharding = named_sharding(mesh, ("batch", None),
                                  (c.global_batch, c.seq_len))

        def make(index):
            # per-shard deterministic generation: fold in the batch offset
            start = index[0].start or 0
            stop = index[0].stop or c.global_batch
            sub = jax.random.fold_in(key, start)
            toks = np.asarray(self._sample(sub, stop - start))
            return toks

        full = jax.make_array_from_callback(
            (c.global_batch, c.seq_len + 1),
            named_sharding(mesh, ("batch", None),
                           (c.global_batch, c.seq_len + 1)),
            make)
        return {"tokens": full[:, :-1], "labels": full[:, 1:]}
