"""AdamW (paper Eq. 1) — own implementation (no optax dependency).

The bounded-update property (paper Thm 2: |ΔW_t| ≤ η) that MOSS's
automatic scaling relies on is a property of this update rule; the test
suite checks it empirically against this implementation.

State is a pytree-of-OptState threaded through the jitted train step and
sharded like the parameters (ZeRO: moments inherit the param sharding,
which is FSDP×TP here).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: jax.Array
    nu: jax.Array


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95          # paper: LLM-typical beta2
    eps: float = 1e-8
    weight_decay: float = 0.1


def init_opt_state(params):
    return jax.tree.map(
        lambda w: OptState(mu=jnp.zeros_like(w, jnp.float32),
                           nu=jnp.zeros_like(w, jnp.float32)), params)


def adamw_update(cfg: AdamWConfig, params, grads, state, step, lr):
    """Returns (new_params, new_state).  step is 1-based inside."""
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(w, g, st):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * st.mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * st.nu + (1.0 - cfg.b2) * jnp.square(g)
        mhat = mu / c1
        vhat = nu / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        w32 = w.astype(jnp.float32)
        w_new = w32 - lr * (delta + cfg.weight_decay * w32)
        return w_new.astype(w.dtype), OptState(mu=mu, nu=nu)

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    s_leaves = treedef.flatten_up_to(state)
    out = [upd(w, g, st) for w, g, st in zip(p_leaves, g_leaves, s_leaves)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, new_state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor
                                   ).astype(g.dtype), grads), norm
