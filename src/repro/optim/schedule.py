"""LR schedules (paper §4.1: cosine decay to 10% of peak, linear warmup)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr: float, warmup_steps: int,
                       total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    floor = peak_lr * final_frac
    cos = floor + 0.5 * (peak_lr - floor) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)


def constant_lr(step, *, peak_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak_lr)
