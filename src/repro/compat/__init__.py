# Version-compat shims isolating the repo from breaking upstream API
# changes.  Everything jax-version-dependent goes through compat.jaxapi.
