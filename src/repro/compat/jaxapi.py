"""JAX version-compat shim — single choke point for APIs that moved
between jax 0.4.x and 0.5+/0.6+.

The pinned toolchain is jax 0.4.37; newer jax renamed or added:

  =====================================  ==================================
  newer jax                              0.4.37 equivalent
  =====================================  ==================================
  ``jax.sharding.AxisType``              (absent — meshes are all "auto")
  ``jax.make_mesh(..., axis_types=)``    ``jax.make_mesh(shape, names)``
  ``jax.sharding.get_abstract_mesh()``   ``jax._src.mesh.thread_resources``
  ``jax.shard_map(check_vma=)``          ``jax.experimental.shard_map``
                                         ``.shard_map(check_rep=)``
  ``pallas.tpu.CompilerParams``          ``pallas.tpu.TPUCompilerParams``
  =====================================  ==================================

Import from here, never feature-test at call sites:

    from repro.compat.jaxapi import AxisType, make_mesh, shard_map
"""

from __future__ import annotations

import enum
from typing import Any, Sequence

import numpy as np

import jax

__all__ = [
    "AxisType",
    "ClosedJaxpr",
    "HAS_AXIS_TYPES",
    "Jaxpr",
    "abstract_mesh",
    "cost_analysis",
    "make_mesh",
    "mesh_from_devices",
    "pallas_tpu_compiler_params",
    "shard_map",
]


# --------------------------------------------------------------------------
# AxisType / axis_types kwarg
# --------------------------------------------------------------------------

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x: every mesh axis behaves like "Auto"

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False


def _axis_types_kwargs(axis_types, n_axes: int) -> dict[str, Any]:
    if not HAS_AXIS_TYPES:
        return {}
    if axis_types is None:
        axis_types = (AxisType.Auto,) * n_axes
    return {"axis_types": axis_types}


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with the ``axis_types`` kwarg dropped on jax
    versions that don't know it (where all axes are implicitly Auto)."""
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         **_axis_types_kwargs(axis_types, len(axis_names)))


def mesh_from_devices(devices, axis_names: Sequence[str],
                      axis_types=None) -> jax.sharding.Mesh:
    """``Mesh(devices, names)`` from an explicit (nested) device array,
    portable across the axis_types API change."""
    dev = np.asarray(devices)
    return jax.sharding.Mesh(
        dev, tuple(axis_names),
        **_axis_types_kwargs(axis_types, len(axis_names)))


# --------------------------------------------------------------------------
# Active-mesh introspection
# --------------------------------------------------------------------------


def abstract_mesh() -> jax.sharding.Mesh | None:
    """The mesh of the enclosing ``with mesh:`` scope, or None.

    Newer jax exposes this as ``jax.sharding.get_abstract_mesh()``; on
    0.4.x the same information lives in the thread-local resource env.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        am = get()
        if am is not None and not am.empty:
            return am
        return None
    from jax._src import mesh as mesh_lib  # 0.4.x fallback

    pm = mesh_lib.thread_resources.env.physical_mesh
    if pm is not None and not pm.empty:
        return pm
    return None


# --------------------------------------------------------------------------
# shard_map
# --------------------------------------------------------------------------

if hasattr(jax, "shard_map"):  # jax >= 0.6

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        # check_vma (varying-mesh-axes) is the successor of check_rep
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


# --------------------------------------------------------------------------
# Jaxpr / ClosedJaxpr classes (for jaxpr introspection)
# --------------------------------------------------------------------------

try:  # newer jax: jax.core.Jaxpr deprecated/removed in favor of extend
    from jax.extend.core import ClosedJaxpr, Jaxpr  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.core import ClosedJaxpr, Jaxpr  # type: ignore[no-redef]


# --------------------------------------------------------------------------
# Compiled-executable cost analysis
# --------------------------------------------------------------------------


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict.

    jax 0.4.x returns a one-element list of per-program dicts; newer jax
    returns the dict directly.  Returns {} when XLA provides nothing.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


# --------------------------------------------------------------------------
# Pallas TPU compiler params
# --------------------------------------------------------------------------


def pallas_tpu_compiler_params(*, dimension_semantics=None):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (old),
    built lazily so importing this module never pulls in Pallas."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics)
