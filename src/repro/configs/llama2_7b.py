"""LLaMA-2-7B — the paper's fine-tuning base model (paper Table 8:
32L d=4096 32H, seq 4096).  [arXiv:2307.09288]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=11_008,
    vocab=32_000,
    d_head=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    d_head=32, attn_chunk=64, remat=False)
