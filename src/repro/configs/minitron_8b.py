"""minitron-8b [dense]: 32L d=4096 32H (GQA kv=8) d_ff=16384,
vocab 256000 — pruned Nemotron-4 (squared-ReLU MLP, partial rotary,
LayerNorm).  [arXiv:2407.14679; hf]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=16_384,
    vocab=256_000,
    d_head=128,
    act="relu2",
    norm="layernorm",
    rope_pct=0.5,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    d_head=32, attn_chunk=64, remat=False)
