"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H MLA(kv_lora=512) MoE 64e
top-6 + 2 shared, vocab 102400.  [arXiv:2405.04434; hf]

Note: the assignment line reads "64e top-6 ... 2 shared+160 routed"; the
published DeepSeek-V2-Lite config has 64 routed experts (160 routed is
the full V2) — we follow the 64-routed/2-shared/top-6 reading and record
the discrepancy here.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="mla_moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,                 # per-expert intermediate
    vocab=102_400,
    d_head=128,
    n_experts=64,
    n_shared=2,
    top_k=6,
    first_dense=1,
    dense_ff=10_944,
    kv_lora=512,
    q_nope=128,
    q_rope=64,
    v_head=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=3, d_model=128, n_heads=4, n_kv=4, d_ff=96, vocab=512,
    d_head=32, n_experts=8, top_k=2, n_shared=1, first_dense=1,
    dense_ff=256, kv_lora=64, q_nope=32, q_rope=16, v_head=32,
    attn_chunk=64, remat=False)
