"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=6400,
16 experts top-2, vocab 32064.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]

PhiMoE uses LayerNorm and sparsemixer routing; we use standard top-2
softmax routing (noted simplification) with LayerNorm.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=6400,
    vocab=32_064,
    d_head=128,
    n_experts=16,
    top_k=2,
    act="swiglu",
    norm="layernorm",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=160, vocab=512,
    d_head=32, n_experts=8, top_k=2, attn_chunk=64, remat=False)
