"""rwkv6-3b (Finch) [ssm]: 32L d=2560 attention-free, d_ff=8960,
vocab 65536 — data-dependent decay WKV.  [arXiv:2404.05892; hf]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,              # d_model / rwkv_head_dim
    n_kv=40,
    d_ff=8960,
    vocab=65_536,
    rwkv_head_dim=64,
    ddlerp_rank=32,
    decay_rank=64,
    act="relu2",
    norm="layernorm",
    pos_embedding="none",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=2, n_kv=2, d_ff=256, vocab=512,
    rwkv_head_dim=64, ddlerp_rank=8, decay_rank=16, remat=False)
