"""h2o-danube-3-4b [dense]: 24L d=3840 32H (GQA kv=8) d_ff=10240,
vocab 32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv=8,
    d_ff=10_240,
    vocab=32_000,
    d_head=120,
    attn_type="swa",
    window=4096,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    d_head=32, window=64, attn_chunk=32, remat=False)
