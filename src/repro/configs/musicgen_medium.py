"""musicgen-medium [audio]: 48L d=1536 24H (MHA) d_ff=6144, vocab 2048 —
decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only (harness note): the EnCodec frontend + 4-codebook delay
pattern are STUBBED — ``input_specs()`` provides precomputed frame
embeddings (B, S, d) and single-stream labels over the 2048-entry
codebook.  Sinusoidal positions, LayerNorm, GELU MLP.
24 heads do not divide the 16-way model axis: attention activations run
data-parallel (heads replicated); FFN/projection matmuls still
tensor-shard on d_ff/d_model (DESIGN.md §6).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv=24,
    d_ff=6144,
    vocab=2048,
    d_head=64,
    act="gelu_mlp",
    norm="layernorm",
    input_mode="embeddings",
    pos_embedding="sinusoidal",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=96, n_heads=3, n_kv=3, d_ff=192, vocab=128,
    d_head=32, attn_chunk=64, remat=False)
