"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) d_ff=7680,
vocab 256000 — RG-LRU + local attention, 1 attention per 2 recurrent
blocks.  [arXiv:2402.19427; hf]

26 layers = 8 × (rec, rec, local-attn) + 2 trailing recurrent blocks.
Gemma-style: tied embeddings, sqrt(d) embed scale, GeGLU, logit softcap.
10 heads don't divide the model axis; head_dim=256, local window 2048.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256_000,
    d_head=256,
    attn_type="local",
    window=2048,
    lru_width=2560,
    conv_width=4,
    act="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    embed_scale=True,
    logit_softcap=30.0,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=6, d_model=128, n_heads=2, n_kv=1, d_ff=256, vocab=512,
    d_head=64, window=64, lru_width=128, attn_chunk=32, remat=False)
