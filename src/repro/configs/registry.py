"""--arch id -> config module registry."""

from __future__ import annotations

import importlib

from .base import ModelConfig, SHAPES, shape_applicable

ARCHS: dict[str, str] = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "stablelm-12b": "stablelm_12b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "phi3-mini-3.8b": "phi3_mini_38b",
    "minitron-8b": "minitron_8b",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "phi-3-vision-4.2b": "phi3_vision_42b",
    "rwkv6-3b": "rwkv6_3b",
    # paper's own models
    "olmo-7b": "olmo_7b",
    "llama2-7b": "llama2_7b",
}

ASSIGNED = [a for a in ARCHS if a not in ("olmo-7b", "llama2-7b")]


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def iter_cells():
    """All (arch, shape, runnable, skip_reason) dry-run cells."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = shape_applicable(cfg, shape)
            yield arch, shape, ok, reason
