"""phi-3-vision-4.2b [vlm]: phi3-mini backbone (32L d=3072 32H MHA
d_ff=8192 vocab 32064) + CLIP frontend.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Backbone only (harness note): the CLIP image tower is STUBBED —
``input_specs()`` provides precomputed patch+text embeddings (B, S, d);
labels target the text token stream.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32_064,
    d_head=96,
    act="swiglu",
    norm="rmsnorm",
    input_mode="embeddings",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    d_head=32, attn_chunk=64, remat=False)
