"""stablelm-12b [dense]: 40L d=5120 32H (GQA kv=8) d_ff=13824,
vocab 100352.  [hf:stabilityai/stablelm-2-12b; hf]

StableLM-2-12B: LayerNorm, partial rotary (25%), per-head qk-norm.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    d_ff=13_824,
    vocab=100_352,
    d_head=160,
    act="swiglu",
    norm="layernorm",
    rope_pct=0.25,
    qk_norm=True,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    d_head=32, attn_chunk=64, remat=False)
