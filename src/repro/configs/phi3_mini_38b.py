"""phi3-mini-3.8b [dense]: 32L d=3072 32H (GQA kv=32 = MHA) d_ff=8192,
vocab 32064 — RoPE SwiGLU.  [arXiv:2404.14219; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32_064,
    d_head=96,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    d_head=32, attn_chunk=64, remat=False)
