"""Model configuration dataclass + input-shape registry.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the full published config) and ``SMOKE_CONFIG`` (a reduced
same-family config for CPU smoke tests).  ``repro.configs.registry``
maps ``--arch`` ids to them.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

from repro.core.formats import QuantConfig, MOSS_CONFIG


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "mla_moe", "hybrid", "ssm",
                    "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                    # 0 -> d_model // n_heads

    # --- attention ---
    attn_type: Literal["full", "swa", "local"] = "full"
    window: int = 4096
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0              # partial rotary (stablelm: 0.25)
    qk_norm: bool = False
    logit_softcap: float = 0.0         # gemma-style final-logit softcap

    # --- FFN ---
    act: Literal["swiglu", "geglu", "gelu_mlp", "relu2"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"

    # --- MoE ---
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    capacity_factor: float = 1.3
    dense_ff: int = 0                  # width of non-MoE FFN layers
    first_dense: int = 0               # leading layers with dense FFN

    # --- MLA (deepseek) ---
    kv_lora: int = 0
    q_nope: int = 128
    q_rope: int = 64
    v_head: int = 128

    # --- hybrid (recurrentgemma) ---
    block_pattern: tuple[str, ...] = ("attn",)   # repeating unit
    lru_width: int = 0
    conv_width: int = 4

    # --- rwkv ---
    rwkv_head_dim: int = 64
    ddlerp_rank: int = 32
    decay_rank: int = 64

    # --- io / misc ---
    input_mode: Literal["tokens", "embeddings"] = "tokens"
    pos_embedding: Literal["rope", "sinusoidal", "none"] = "rope"
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma: scale embeddings by sqrt(d)
    norm_eps: float = 1e-5

    # --- training-shape knobs ---
    attn_chunk: int = 512              # flash-chunk size (queries and kv)
    # KV-cache storage for the decode-bound serving shapes: fp8 (e4m3
    # payload + per-(token, kv-head) f32 scales) by default — decode is
    # memory-roofline-bound and the cache read dominates, so 1 byte/
    # element ~halves step HBM traffic (benchmarks/roofline.py).
    # "bf16" is the exactness escape hatch; REPRO_KV_CACHE overrides
    # either way at cache init (models/attention.py).  Training never
    # builds caches, so this has no effect on the training path; MLA's
    # absorbed latent cache ignores it.
    kv_cache_dtype: Literal["bf16", "fp8"] = "fp8"
    moe_decode_dense: bool = True      # decode path: masked dense experts
    remat: bool = True
    scan_layers: bool = True

    # quantization recipe (the paper's contribution; swap for baselines)
    quant: QuantConfig = MOSS_CONFIG

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports long_500k: SSM / hybrid / sliding-window archs."""
        return (self.family in ("ssm", "hybrid")
                or self.attn_type in ("swa", "local"))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assigned input-shape set (same four for every LM arch).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason when skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k dense-KV decode is "
                       "the quadratic regime this shape excludes "
                       "(DESIGN.md §6)")
    return True, ""
