"""Training driver: real training on CPU/TPU at any scale.

Fault-tolerance contract (DESIGN.md §4):
  - checkpoint manager with atomic commits + resume-from-latest
  - SIGTERM/SIGINT → checkpoint-and-exit (preemption-safe)
  - deterministic stateless data pipeline (step -> batch)
  - elastic restore: checkpoints reshard onto whatever mesh is current

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-7b --smoke \
      --steps 200 --batch 8 --seq 128 [--quant moss|bf16|per_tensor|...]
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import jax

from repro.checkpoint import manager as ckpt
from repro.configs.registry import get_config
from repro.core.formats import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.obs.trace import span, trace_enabled
from repro.train.steps import TrainHParams, init_train_state, make_train_step

_PREEMPTED = False


def _handle_preempt(signum, frame):
    global _PREEMPTED
    _PREEMPTED = True


def quant_from_name(name: str, interval: int = 500,
                    grad_comm_fp8: bool = False) -> QuantConfig:
    if name == "bf16":
        return QuantConfig(mode="bf16", grad_comm_fp8=grad_comm_fp8)
    scaling = "auto" if name == "moss" else "jit"
    return QuantConfig(mode=name if name != "moss" else "moss",
                       weight_scaling=scaling, rescale_interval=interval,
                       grad_comm_fp8=grad_comm_fp8)


def train(arch: str, *, smoke: bool = True, steps: int = 100,
          batch: int = 8, seq: int = 128, quant: str = "moss",
          lr: float = 3e-4, warmup: int = 20, ckpt_dir: str | None = None,
          ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
          mesh=None, microbatches: int = 1, interval: int = 500,
          grad_comm_fp8: bool = False, log=print):
    cfg = get_config(arch, smoke=smoke).replace(
        quant=quant_from_name(quant, interval, grad_comm_fp8))
    hp = TrainHParams(peak_lr=lr, warmup_steps=warmup, total_steps=steps,
                      microbatches=microbatches)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=seed))

    state = init_train_state(cfg, hp, jax.random.PRNGKey(seed))
    start_step = 0
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        state, start_step = ckpt.restore(ckpt_dir, state)
        log(f"resumed from checkpoint at step {start_step}")

    step_fn = make_train_step(cfg, hp, mesh)
    ctx = use_mesh(mesh) if mesh is not None else _nullcontext()
    signal.signal(signal.SIGTERM, _handle_preempt)

    history = []
    with ctx:
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        tokens_done = 0
        for step in range(start_step, steps):
            with span("train.data", step=step):
                b = data.batch_for_step(step, mesh)
                if cfg.input_mode == "embeddings":
                    # modality-frontend stub: embed tokens with a fixed
                    # random projection (precomputed frame/patch
                    # embeddings)
                    b = dict(b)
                    b["embeds"] = _stub_embeds(cfg, b["tokens"])
            with span("train.step", step=step):
                state, metrics = jitted(state, b)
                # spans wrap host wall time; blocking on the loss makes
                # the span end-to-end instead of measuring dispatch
                if trace_enabled():
                    jax.block_until_ready(metrics["loss"])
            tokens_done += batch * seq
            if (step + 1) % log_every == 0 or step + 1 == steps:
                loss = float(metrics["loss"])
                tps = tokens_done / (time.time() - t0)
                log(f"step {step+1:5d} loss {loss:.4f} "
                    f"lr {float(metrics['lr']):.2e} "
                    f"gnorm {float(metrics['grad_norm']):.2f} "
                    f"tok/s {tps:,.0f}")
                history.append((step + 1, loss))
            if ckpt_dir and ((step + 1) % ckpt_every == 0 or _PREEMPTED
                             or step + 1 == steps):
                ckpt.save(ckpt_dir, step + 1, state)
                if _PREEMPTED:
                    log("preemption signal: checkpointed, exiting")
                    sys.exit(42)
    return state, history


def _stub_embeds(cfg, tokens):
    import jax.numpy as jnp
    key = jax.random.PRNGKey(1234)
    table = jax.random.normal(key, (cfg.vocab, cfg.d_model),
                              jnp.float32) * 0.02
    return jnp.take(table, tokens, axis=0).astype(jnp.bfloat16)


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quant", default="moss",
                    choices=["moss", "bf16", "per_tensor", "per_group"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-comm-fp8", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="'host:<model>' to train over all local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mesh = None
    if args.mesh and args.mesh.startswith("host"):
        model = int(args.mesh.split(":")[1]) if ":" in args.mesh else 1
        mesh = make_host_mesh(model=model)

    train(args.arch, smoke=args.smoke, steps=args.steps,
          batch=args.batch, seq=args.seq, quant=args.quant, lr=args.lr,
          ckpt_dir=args.ckpt_dir, microbatches=args.microbatches,
          grad_comm_fp8=args.grad_comm_fp8, mesh=mesh, seed=args.seed)


if __name__ == "__main__":
    main()
