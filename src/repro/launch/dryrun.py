import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell
with ShapeDtypeStruct inputs — proving the distribution config is
coherent without hardware — and record memory/cost/collective stats for
the roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.compat import jaxapi
from repro.core import runtime_flags
from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ASSIGNED, get_config
from repro.distributed.sharding import use_mesh
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.train.steps import (
    TrainHParams,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e8m0fnu": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


# Per-device wire-byte factors (ring algorithms, large n): an all-reduce
# moves ~2x its (per-device) result shape over the links; gather/scatter/
# a2a/permute move ~1x.
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                "reduce-scatter": 1.0, "all-to-all": 1.0,
                "collective-permute": 1.0}

_RESULT_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]))\S*\s+(" + "|".join(_COLLECTIVES)
    + r")(?:-start)?\(")
_WHILE_RE = re.compile(
    r"while\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_COMP_RE = re.compile(r"^%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*(?://.*)?$")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    depth = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if cur is None:
            if s.endswith("{") and ("(" in s or s.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", s)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(s)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic: the loop bound constant in the while condition."""
    consts = []
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective wire bytes, including collectives inside
    while loops (scan-over-layers!) multiplied by their trip counts."""
    comps = _split_computations(hlo_text)
    # map computation -> ENTRY? figure entry name
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            entry = m.group(1) if m else None
            break
    if entry is None or entry not in comps:
        entry = next(iter(comps)) if comps else None

    bytes_by = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    by_dtype: dict[str, float] = {}
    calls_seen: set[str] = set()

    def shape_bytes(shape_str: str, mult: float = 0.0) -> int:
        total = 0
        for dt, dims in _SHAPE_RE.findall(shape_str):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            b = n * _DTYPE_BYTES.get(dt, 4)
            total += b
            if mult:
                by_dtype[dt] = by_dtype.get(dt, 0.0) + b * mult
        return total

    def walk(comp: str, mult: float):
        if comp not in comps:
            return
        key = f"{comp}@{mult}"
        if key in calls_seen:     # defensive against cycles
            return
        calls_seen.add(key)
        for ln in comps[comp]:
            m = _RESULT_RE.search(ln)
            if m:
                tuple_shapes, single, coll = m.groups()
                b = shape_bytes(tuple_shapes or single or "", mult)
                bytes_by[coll] += b * mult
                counts[coll] += int(mult)
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.groups()
                trips = _trip_count(comps.get(cond, []))
                walk(body, mult * trips)
            else:
                # follow call/fusion-to-computation edges
                cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ln)
                if cm and cm.group(1) in comps:
                    walk(cm.group(1), mult)

    if entry:
        walk(entry, 1.0)
    wire = sum(_WIRE_FACTOR[k] * v for k, v in bytes_by.items())
    return {"bytes": {k: int(v) for k, v in bytes_by.items()},
            "counts": counts,
            "bytes_by_dtype": {k: int(v) for k, v in by_dtype.items()},
            "total_bytes": int(sum(bytes_by.values())),
            "wire_bytes_per_device": int(wire)}


def _memory_stats(compiled) -> dict:
    m = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    stats = {k: int(getattr(m, k, 0)) for k in keys}
    stats["total_per_device"] = (stats["argument_size_in_bytes"]
                                 + stats["output_size_in_bytes"]
                                 + stats["temp_size_in_bytes"]
                                 - stats["alias_size_in_bytes"])
    return stats


def default_microbatches(cfg) -> int:
    """Bound the per-layer activation carry: wider residual streams,
    deeper stacks, and many-expert MoE (dispatch buffers) get more
    gradient-accumulation steps."""
    if cfg.d_model * cfg.n_layers >= 160_000 or cfg.n_experts >= 32:
        return 8
    return 4


def segment_probes(cfg, shape, mesh, n_mb: int) -> dict:
    """XLA's cost_analysis counts a while body ONCE, so scan-over-layers
    (and the microbatch scan) under-report FLOPs/bytes.  We compile a
    per-segment single-unit probe at the in-loop shapes and scale:

      adjusted = full + Σ_seg (reps_seg − 1) · probe_seg

    where reps = n_layers·n_microbatches (train) or n_layers (serve).
    The probe is fwd+bwd for train, fwd for prefill/decode — matching
    what the scan body contains.
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.core.linear import QT
    from repro.distributed.sharding import resolve_spec
    from repro.models.layers import (abstract_tree, quant_mask_tree,
                                     spec_tree)
    from repro.models.transformer import build_segments

    qcfg = cfg.quant
    kind = shape.kind
    b = shape.global_batch // (n_mb if kind == "train" else 1)
    s = 1 if kind == "decode" else shape.seq_len
    x_abs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    x_sh = NamedSharding(mesh, resolve_spec(("batch", None, "embed"),
                                            mesh, x_abs.shape))
    positions = (0 if kind == "decode" else None)

    from repro.train.steps import _scale_dims

    probes = {}
    for seg in build_segments(cfg):
        mask = quant_mask_tree(seg.defs)
        sdims = _scale_dims(seg.defs)
        p_abs = abstract_tree(seg.defs)
        p_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                            spec_tree(seg.defs, mesh))
        mask_flat, treedef = jax.tree.flatten(mask)
        abs_flat = treedef.flatten_up_to(p_abs)
        sd_flat = treedef.flatten_up_to(sdims)
        # scales as traced args (constants would constant-fold slowly)
        sc_abs = tuple(jax.ShapeDtypeStruct(d.shape[:nd], jnp.float32)
                       for d, m, nd in zip(abs_flat, mask_flat, sd_flat)
                       if m)
        sc_sh = tuple(NamedSharding(mesh, resolve_spec((), mesh))
                      for _ in sc_abs)

        def wrap(p_l, sc, mask_flat=mask_flat, treedef=treedef):
            leaves = treedef.flatten_up_to(p_l)
            it = iter(sc)
            out = [QT(w, next(it)) if m else w
                   for w, m in zip(leaves, mask_flat)]
            return jax.tree.unflatten(treedef, out)

        if kind == "train":
            def probe_fn(p_l, sc, x, seg=seg, wrap=wrap):
                pos = jnp.arange(x.shape[1], dtype=jnp.int32)

                def f(p_l, x):
                    y, _, aux = seg.apply(cfg, qcfg, wrap(p_l, sc), x,
                                          pos, None, "train")
                    return y.astype(jnp.float32).sum() + aux

                if cfg.remat:   # match the scanned body: remat recompute
                    f = jax.checkpoint(f, prevent_cse=False)
                return jax.grad(f, argnums=(0, 1))(p_l, x)

            args, shs = (p_abs, sc_abs, x_abs), (p_sh, sc_sh, x_sh)
        else:
            cache_abs = (jax.eval_shape(
                lambda: seg.init_cache(cfg, shape.global_batch,
                                       shape.seq_len))
                if seg.init_cache else None)
            cache_sh = None
            if cache_abs is not None and seg.cache_logical:
                logical = seg.cache_logical(cfg)
                cache_sh = jax.tree.map(
                    lambda ax, leaf: NamedSharding(
                        mesh, resolve_spec(tuple(ax), mesh, leaf.shape)),
                    logical, cache_abs,
                    is_leaf=lambda x: isinstance(x, tuple)
                    and all(isinstance(e, (str, type(None))) for e in x))

            def probe_fn(p_l, sc, x, cache, seg=seg, kind=kind,
                         wrap=wrap):
                pos = (jnp.zeros((1,), jnp.int32) if kind == "decode"
                       else jnp.arange(x.shape[1], dtype=jnp.int32))
                y, c, _ = seg.apply(cfg, qcfg, wrap(p_l, sc), x, pos,
                                    cache, kind)
                return y, c

            args = (p_abs, sc_abs, x_abs, cache_abs)
            shs = (p_sh, sc_sh, x_sh, cache_sh)

        donate = (3,) if kind != "train" and args[3] is not None else ()
        compiled = jax.jit(probe_fn, in_shardings=shs,
                           donate_argnums=donate).lower(*args).compile()
        cost = jaxapi.cost_analysis(compiled)
        reps = seg.n * (n_mb if kind == "train" else 1)
        probes[seg.name] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "reps": reps,
        }
    return probes


def build_cell(arch: str, shape_name: str, mesh, overrides=None):
    """Returns (fn, abstract_args, in_shardings, donate) for one cell.
    ``overrides``: dict of ModelConfig.replace / hparam knobs for the
    §Perf hillclimb (e.g. {"microbatches": 8, "attn_chunk": 1024})."""
    import dataclasses as _dc

    from repro.core.formats import QuantConfig

    overrides = dict(overrides or {})
    n_mb = overrides.pop("microbatches", None)
    cfg = get_config(arch)
    q_kw = {k: v for k, v in overrides.items()
            if k in QuantConfig.__dataclass_fields__}
    if q_kw:
        cfg = cfg.replace(quant=_dc.replace(cfg.quant, **q_kw))
    cfg_kw = {k: v for k, v in overrides.items()
              if k in type(cfg).__dataclass_fields__}
    if cfg_kw:
        cfg = cfg.replace(**cfg_kw)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        hp = TrainHParams(
            microbatches=n_mb or default_microbatches(cfg))
        fn = make_train_step(cfg, hp, mesh)
        state = S.state_abstract(cfg)
        state_sh = S.state_shardings(cfg, mesh)
        batch, batch_sh = S.batch_specs(cfg, shape, mesh)
        return fn, (state, batch), (state_sh, batch_sh), (0,)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, max_len=shape.seq_len)
        params = S.params_abstract(cfg)
        params_sh = S.params_shardings(cfg, mesh)
        batch, batch_sh = S.batch_specs(cfg, shape, mesh)
        return fn, (params, batch), (params_sh, batch_sh), ()
    # decode
    import jax.numpy as jnp

    fn = make_decode_step(cfg)
    pdt = overrides.pop("serve_params_dtype", None)
    params = S.params_abstract(
        cfg, jnp.bfloat16 if pdt == "bf16" else None)
    params_sh = S.params_shardings(cfg, mesh)
    caches = S.caches_abstract(cfg, shape)
    caches_sh = S.caches_shardings(cfg, shape, mesh)
    toks = S.decode_tokens_abstract(cfg, shape)
    toks_sh = S.decode_tokens_sharding(cfg, shape, mesh)
    return fn, (params, caches, toks), (params_sh, caches_sh, toks_sh), (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, preset: str = "2d",
             overrides=None, tag: str = "") -> dict:
    from repro.distributed.presets import preset_rules
    from repro.distributed.sharding import sharding_rules

    runtime_flags.force_bf16_operands(True)   # TPU operand widths in HLO
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "n_devices": mesh.size, "preset": preset,
              "overrides": {k: str(v) for k, v in
                            (overrides or {}).items()}}
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, SHAPES[shape_name])
    if not ok:
        record.update(status="skipped", reason=reason)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            path = os.path.join(
                out_dir, f"{arch}__{shape_name}__{mesh_name}{tag}.json")
            with open(path, "w") as f:
                json.dump(record, f, indent=1)
        return record
    try:
        with sharding_rules(preset_rules(preset)):
            fn, args, shardings, donate = build_cell(
                arch, shape_name, mesh, overrides)
        shape = SHAPES[shape_name]
        n_mb = ((overrides or {}).get("microbatches")
                or (default_microbatches(cfg) if shape.kind == "train"
                    else 1))
        with use_mesh(mesh), sharding_rules(preset_rules(preset)):
            jitted = jax.jit(fn, in_shardings=shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            cost = jaxapi.cost_analysis(compiled)
            mem = _memory_stats(compiled)
            coll = parse_collectives(compiled.as_text())
            probes = segment_probes(cfg, shape, mesh, n_mb)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        flops_adj = flops + sum(p["flops"] * (p["reps"] - 1)
                                for p in probes.values())
        bytes_adj = bytes_acc + sum(p["bytes"] * (p["reps"] - 1)
                                    for p in probes.values())
        record.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=flops,
            bytes_accessed=bytes_acc,
            flops_adjusted=flops_adj,
            bytes_adjusted=bytes_adj,
            probes=probes,
            memory=mem,
            collectives=coll,
        )
    except Exception as e:  # record failures for triage
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    record["wall_s"] = round(time.time() - t0, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}{tag}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--preset", default="2d")
    ap.add_argument("--tag", default="",
                    help="suffix for the artifact filename (§Perf runs)")
    ap.add_argument("--set", action="append", default=[],
                    help="knob override, e.g. --set microbatches=8 "
                         "--set attn_chunk=1024")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = (int(v) if v.lstrip("-").isdigit()
                        else (v == "True" if v in ("True", "False")
                              else v))

    if args.all:
        cells = [(a, s, mp) for a in ASSIGNED for s in SHAPES
                 for mp in (False, True)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, mp, args.out, preset=args.preset,
                       overrides=overrides, tag=args.tag)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"flops={rec['flops_adjusted']:.3e} "
                     f"coll={rec['collectives']['total_bytes']:.3e}B "
                     f"mem={rec['memory']['total_per_device']/2**30:.2f}GiB "
                     f"[{rec['wall_s']}s]")
        elif status == "error":
            extra = rec["error"][:160]
            failures += 1
        print(f"{rec['mesh']:12s} {arch:24s} {shape:12s} {status:8s} "
              f"{extra}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
