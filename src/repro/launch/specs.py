"""Abstract inputs (ShapeDtypeStruct) + shardings for every
(arch × shape × mesh) dry-run cell — the shannon/kernels pattern:
weak-type-correct, shardable, zero device allocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import resolve_spec
from repro.models.layers import abstract_tree, spec_tree
from repro.models.transformer import (
    cache_logical_tree,
    init_caches,
    model_defs,
)
from repro.optim.adamw import OptState
from repro.train.steps import TrainState, _scale_dims


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(abstract batch, batch shardings) for a train/prefill step."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        s = 1
    tree, logical = {}, {}
    if cfg.input_mode == "embeddings":
        tree["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        logical["embeds"] = ("batch", None, "embed")
    else:
        tree["tokens"] = _sds((b, s), jnp.int32)
        logical["tokens"] = ("batch", None)
    if shape.kind == "train":
        tree["labels"] = _sds((b, s), jnp.int32)
        logical["labels"] = ("batch", None)
        if cfg.input_mode == "embeddings":
            tree["tokens"] = _sds((b, s), jnp.int32)
            logical["tokens"] = ("batch", None)
    shardings = {
        k: NamedSharding(mesh, resolve_spec(logical[k], mesh,
                                            tree[k].shape))
        for k in tree
    }
    return tree, shardings


def params_abstract(cfg: ModelConfig, dtype=None):
    tree = abstract_tree(model_defs(cfg))
    if dtype is None:
        return tree
    # serving checkpoints store reduced-precision weights (e.g. bf16):
    # halves the per-step parameter HBM read of decode
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if s.dtype == jnp.float32 else s.dtype), tree)


def params_shardings(cfg: ModelConfig, mesh):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        spec_tree(model_defs(cfg), mesh))


def state_abstract(cfg: ModelConfig, qcfg=None):
    """Abstract TrainState (no allocation)."""
    qcfg = qcfg or cfg.quant
    defs = model_defs(cfg)
    params = abstract_tree(defs)
    opt = jax.tree.map(
        lambda p: OptState(mu=_sds(p.shape, jnp.float32),
                           nu=_sds(p.shape, jnp.float32)), params)
    sdims = _scale_dims(defs)
    s0 = jax.tree.map(lambda p, n: _sds(p.shape[:n], jnp.float32),
                      params, sdims)
    t = jax.tree.map(lambda p: _sds((), jnp.int32), params)
    res = (jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params)
           if qcfg.grad_comm_fp8 else None)
    return TrainState(params=params, opt=opt, scale_s0=s0, scale_t=t,
                      comm_residual=res, step=_sds((), jnp.int32))


def state_shardings(cfg: ModelConfig, mesh, qcfg=None):
    qcfg = qcfg or cfg.quant
    defs = model_defs(cfg)
    specs = spec_tree(defs, mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = jax.tree.map(ns, specs)
    opt = jax.tree.map(lambda s: OptState(mu=s, nu=s), p_sh)
    sdims = _scale_dims(defs)

    def scale_sh(spec, n):
        from jax.sharding import PartitionSpec as P
        return ns(P(*spec[:n]))

    s0 = jax.tree.map(scale_sh, specs, sdims)
    rep = ns(resolve_spec((), mesh))
    t = jax.tree.map(lambda _: rep, specs)
    res = p_sh if qcfg.grad_comm_fp8 else None
    return TrainState(params=p_sh, opt=opt, scale_s0=s0, scale_t=t,
                      comm_residual=res, step=rep)


def caches_abstract(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, shape.seq_len))


def caches_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    logical = cache_logical_tree(cfg)
    abstract = caches_abstract(cfg, shape)

    def to_sh(ax, leaf):
        return NamedSharding(mesh, resolve_spec(tuple(ax), mesh,
                                                leaf.shape))

    return jax.tree.map(
        to_sh, logical, abstract,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))


def decode_tokens_abstract(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    if cfg.input_mode == "embeddings":
        return _sds((b, 1, cfg.d_model), jnp.bfloat16)
    return _sds((b, 1), jnp.int32)


def decode_tokens_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh):
    ab = decode_tokens_abstract(cfg, shape)
    logical = (("batch", None, "embed") if cfg.input_mode == "embeddings"
               else ("batch", None))
    return NamedSharding(mesh, resolve_spec(logical, mesh, ab.shape))
