"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never
touches jax device state.  Single pod: (16, 16) = 256 chips,
("data", "model").  Multi-pod: (2, 16, 16) = 512 chips,
("pod", "data", "model") — the pod axis is pure data-parallel (gradient
all-reduce crosses pods once per step) and can host pipeline stages via
distributed/pipeline.py.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

from repro.compat.jaxapi import make_mesh, mesh_from_devices


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (see launch/dryrun.py)")
    # more devices than needed (e.g. 512 placeholders, single-pod mesh):
    # take a prefix so both meshes work in one process.
    return mesh_from_devices(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over however many (CPU) devices exist — tests."""
    n = len(jax.devices())
    data = n // model
    dev = np.asarray(jax.devices()[:data * model]).reshape(data, model)
    return mesh_from_devices(dev, ("data", "model"))
