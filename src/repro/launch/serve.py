"""Batched serving driver: continuous-batching loop over a request
queue with prefill + decode steps and per-slot stop handling.

Requests enter a fixed-size batch of decode slots; finished slots are
refilled from the queue (continuous batching a la vLLM, jax-native).

The whole weight stack is pre-quantized to fp8 payloads + scales ONCE
at server build time (``prequantize_params`` -> ``PrequantParams``):
the serving weights are frozen, so quantizing them — or even just
re-reducing ``max|W|`` — inside every prefill/decode step would be
pure waste.  The decode graph therefore contains zero weight quantize
or max-reduction ops and reads 1 byte/element of weight HBM traffic
(the memory-bound decode roofline win); the KV cache is fp8 by default
for the same reason (docs/serving.md), and the decode step consumes it
through the fused Pallas decode-attention kernel — ring masking, scale
application, softmax and the value combine in one launch, zero
cache-sized dequant ops in the decode jaxpr
(docs/decode-attention.md).  ``REPRO_SERVE_PREQUANT=0`` falls back to
cached-scale in-graph quantization; ``REPRO_KV_CACHE=bf16`` restores
the bf16 cache; ``REPRO_DECODE_ATTN=einsum`` pins the scale-folding
einsum decode attention.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
      --smoke --requests 16 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.layers import init_tree
from repro.models.transformer import model_defs
from repro.core.runtime_flags import serve_prequant
from repro.train.steps import (
    make_decode_step,
    make_prefill_step,
    prequantize_params,
    serve_weight_scales,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1)


class Server:
    """Continuous batching: B decode slots over one shared KV cache."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int):
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        # build-time weight pre-quantization: the full fp8 payload +
        # scale stack replaces the f32 params for every serving step —
        # no weight quantize/max-reduction ops in the jitted graphs.
        # REPRO_SERVE_PREQUANT=0 falls back to cached per-tensor
        # scales (in-graph quantize against frozen scales).
        self.prequant = (prequantize_params(cfg, params)
                         if serve_prequant() else None)
        if self.prequant is not None:
            self.params = self.prequant.qweights
            self.scales = self.prequant.scales
        else:
            self.params = params
            self.scales = serve_weight_scales(cfg, params)
        self.prefill = jax.jit(make_prefill_step(cfg, max_len,
                                                 scales=self.scales))
        self.decode = jax.jit(make_decode_step(cfg, scales=self.scales),
                              donate_argnums=(1,))
        self.slots: list[Request | None] = [None] * batch_slots
        self.caches = None

    def _prefill_request(self, req: Request, slot: int):
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, caches = self.prefill(self.params, {"tokens": toks})
        nxt = int(greedy_sample(logits)[0])
        req.out.append(nxt)
        # merge this request's single-row cache into slot `slot`
        if self.caches is None:
            self.caches = _bcast_rows(caches, self.B)
        self.caches = _write_slot(self.caches, caches, slot)

    def step(self, queue: list[Request]):
        # refill free slots
        for i in range(self.B):
            if self.slots[i] is None or self.slots[i].done:
                if queue:
                    req = queue.pop(0)
                    self._prefill_request(req, i)
                    self.slots[i] = req
        # batched decode for active slots
        active = [i for i in range(self.B)
                  if self.slots[i] is not None and not self.slots[i].done]
        if not active or self.caches is None:
            return
        last = np.zeros((self.B, 1), np.int32)
        for i in active:
            last[i, 0] = self.slots[i].out[-1]
        logits, self.caches = self.decode(self.params, self.caches,
                                          jnp.asarray(last))
        nxt = np.asarray(greedy_sample(logits))
        for i in active:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.max_new:
                req.done = True

    def run(self, requests: list[Request], log=print):
        queue = list(requests)
        t0 = time.time()
        steps = 0
        while queue or any(s is not None and not s.done
                           for s in self.slots):
            self.step(queue)
            steps += 1
            if steps > 10_000:
                raise RuntimeError("serving loop did not converge")
        dt = time.time() - t0
        toks = sum(len(r.out) for r in requests)
        log(f"served {len(requests)} requests, {toks} tokens in "
            f"{dt:.2f}s ({toks/dt:,.1f} tok/s, {steps} engine steps)")
        return requests


def _bcast_rows(caches, b):
    """Layer-stacked cache leaves are (L, 1, ...) after a B=1 prefill;
    expand the batch dim to the slot count."""
    def f(c):
        if c.ndim >= 2 and c.shape[1] == 1:
            return jnp.broadcast_to(
                jnp.zeros_like(c), (c.shape[0], b, *c.shape[2:])).copy()
        return c
    return jax.tree.map(f, caches)


def _write_slot(caches_all, caches_one, slot):
    def f(a, o):
        if a.ndim >= 2 and o.ndim == a.ndim and o.shape[1] == 1:
            return a.at[:, slot:slot + 1].set(o.astype(a.dtype))
        return o  # idx scalars: take the new absolute position
    return jax.tree.map(f, caches_all, caches_one)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=args.prompt_len,
                                        dtype=np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    server = Server(cfg, params, args.slots,
                    max_len=args.prompt_len + args.max_new + 1)
    server.run(reqs)


if __name__ == "__main__":
    main()
