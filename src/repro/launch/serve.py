"""Serving CLI: a thin driver over the paged continuous-batching
engine (``repro.serving.Engine``, the default) with the legacy
contiguous-ring ``Server`` as the ``REPRO_SERVE_PAGED=0`` fallback.

The engine layer (docs/continuous-batching.md) owns admission,
page-exhaustion backpressure, per-slot depths and retirement; both
paths share the fp8-at-rest serving stack: weights pre-quantized once
at build (``PrequantParams``; ``REPRO_SERVE_PREQUANT=0`` falls back to
cached-scale in-graph quantization), the fp8 KV cache default
(``REPRO_KV_CACHE=bf16`` restores bf16) and the fused Pallas decode-
attention kernel (``REPRO_DECODE_ATTN=einsum`` pins the scale-folding
einsum path) — see docs/serving.md.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
      --smoke --requests 16 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.runtime_flags import serve_paged
from repro.models.layers import init_tree
from repro.models.transformer import init_caches, model_defs
from repro.serving import Engine, Request, greedy_sample, prepare_weights
from repro.serving.engine import calibrate_serving
from repro.serving.paged_cache import write_row
from repro.serving.scheduler import RequestState, hit_stop
from repro.train.steps import make_decode_step, make_prefill_step

__all__ = ["Engine", "Request", "Server", "greedy_sample", "main"]


class Server:
    """Legacy continuous batching: a FIXED batch of B decode slots over
    one slot-shaped KV cache, FIFO refill — no page accounting, no
    scheduler, no retirement of finished rows from the decode batch
    (the paged ``Engine`` adds all three; this class is the
    ``REPRO_SERVE_PAGED=0`` fallback).

    Correctness note: the cache is allocated ONCE at build with
    per-slot lengths (``init_caches(..., per_slot=True)`` — ``idx`` is
    a (B,) vector), so a refilled request whose prefill length differs
    from the incumbents keeps every slot's depth, ring position and
    validity mask intact.  The historical single shared scalar ``idx``
    was silently clobbered with the newest request's offset on every
    refill, corrupting incumbent slots at different depths."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int):
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        self.params, self.scales, self.prequant = \
            prepare_weights(cfg, params)
        self.act_scales = calibrate_serving(cfg, self.params,
                                            self.scales)
        self._build_steps()
        # slot-shaped caches at build: B rows, per-slot idx vector
        self.caches = init_caches(cfg, batch_slots, max_len,
                                  per_slot=True)
        self.slots: list[Request | None] = [None] * batch_slots

    def _build_steps(self):
        self.prefill = jax.jit(
            make_prefill_step(self.cfg, self.max_len,
                              scales=self.scales,
                              act_scales=self.act_scales))
        self.decode = jax.jit(
            make_decode_step(self.cfg, scales=self.scales,
                             act_scales=self.act_scales),
            donate_argnums=(1,))

    def refresh_act_scales(self, tokens=None, margin=None):
        """Re-calibrate delayed activation scales and rebuild the
        jitted steps (see ``Engine.refresh_act_scales``)."""
        if self.act_scales is None:
            return None
        from repro.core.actscale import calibrate_act_scales

        kw = {} if margin is None else {"margin": margin}
        self.act_scales = calibrate_act_scales(
            self.cfg, self.params, self.scales, tokens=tokens, **kw)
        self._build_steps()
        return self.act_scales

    def _prefill_request(self, req: Request, slot: int):
        req.state = RequestState.RUNNING
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, one = self.prefill(self.params, {"tokens": toks})
        self._on_token(req, int(greedy_sample(logits)[0]))
        # merge this request's single-row cache into slot `slot`,
        # stamping ITS prompt length into idx[slot] only — incumbent
        # slots at other depths are untouched
        self.caches = write_row(self.caches, one, jnp.int32(slot),
                                jnp.int32(len(req.prompt)))

    def _on_token(self, req: Request, token: int):
        req.out.append(token)
        if hit_stop(req, token):
            req.state = RequestState.FINISHED

    def step(self, queue: list[Request]):
        # refill free slots
        for i in range(self.B):
            if self.slots[i] is None or self.slots[i].done:
                if queue:
                    req = queue.pop(0)
                    self._prefill_request(req, i)
                    self.slots[i] = req
        # batched decode for active slots (finished slots still ride
        # along at fixed B — the paged engine retires them instead)
        active = [i for i in range(self.B)
                  if self.slots[i] is not None and not self.slots[i].done]
        if not active:
            return
        last = np.zeros((self.B, 1), np.int32)
        for i in active:
            last[i, 0] = self.slots[i].out[-1]
        logits, self.caches = self.decode(self.params, self.caches,
                                          jnp.asarray(last))
        nxt = np.asarray(greedy_sample(logits))
        for i in active:
            self._on_token(self.slots[i], int(nxt[i]))

    def run(self, requests: list[Request], log=print):
        queue = list(requests)
        t0 = time.time()
        steps = 0
        while queue or any(s is not None and not s.done
                           for s in self.slots):
            self.step(queue)
            steps += 1
            if steps > 10_000:
                raise RuntimeError("serving loop did not converge")
        dt = time.time() - t0
        toks = sum(len(r.out) for r in requests)
        log(f"served {len(requests)} requests, {toks} tokens in "
            f"{dt:.2f}s ({toks/dt:,.1f} tok/s, {steps} engine steps)")
        return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page pool budget (default: fully backed "
                         "slots); smaller values exercise admission "
                         "backpressure")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--legacy", action="store_true",
                    help="force the legacy contiguous-ring Server "
                         "(same as REPRO_SERVE_PAGED=0)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the metrics-registry snapshot as JSON "
                         "at exit (docs/observability.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record engine step spans and write the "
                         "Chrome-trace JSON at exit (same as "
                         "REPRO_TRACE=PATH)")
    args = ap.parse_args()

    tracer = None
    if args.trace_out:
        from repro.obs.trace import get_tracer

        tracer = get_tracer().enable(path=args.trace_out)

    cfg = get_config(args.arch, smoke=args.smoke)
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    # mixed prompt lengths: the paged engine serves them concurrently
    # at their true depths (the legacy ring also stays correct now —
    # per-slot lengths — it just never retires finished rows)
    lens = rng.integers(max(4, args.prompt_len // 2),
                        args.prompt_len + 1, size=args.requests)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=int(n),
                                        dtype=np.int32),
                    max_new=args.max_new)
            for i, n in enumerate(lens)]
    max_len = args.prompt_len + args.max_new + 1
    if args.legacy or not serve_paged():
        print("path: legacy contiguous-ring Server "
              "(REPRO_SERVE_PAGED=0)")
        server = Server(cfg, params, args.slots, max_len=max_len)
        server.run(reqs)
    else:
        print("path: paged continuous-batching engine "
              "(docs/continuous-batching.md)")
        engine = Engine(cfg, params, args.slots, max_len=max_len,
                        page_size=args.page_size,
                        num_pages=args.num_pages)
        engine.run(reqs)
        s = engine.stats()       # publishes engine/sched registry rows
        qh = s.get("quant_health")
        if qh is not None:
            print(f"quant health: {len(qh['sites'])} sites, "
                  f"refresh_recommended={qh['refresh_recommended']}")
    if tracer is not None:
        print(f"trace: {tracer.save()} ({len(tracer)} events)")
    if args.metrics_out:
        from repro.obs.metrics import get_registry

        with open(args.metrics_out, "w") as f:
            f.write(get_registry().to_json(indent=2))
        print(f"metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
