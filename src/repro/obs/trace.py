"""Engine step tracing: Chrome-trace-event JSON spans
(docs/observability.md).

``span("decode", rows=4)`` wraps a host-side phase in a complete
("ph": "X") Chrome trace event; the emitted file loads directly in
Perfetto / chrome://tracing.  The engine wires spans around its step
phases (retire → swap-in → chunk → decode/verify) and the train loop
wraps its steps — always around the *jitted calls*, never inside a
traced function, so tracing can never change a jaxpr.

Gates and cost:

  - off (the default): ``span()`` returns a shared no-op context
    manager — one dict lookup and zero allocations per call;
  - ``REPRO_TRACE=path``: spans record into a RING BUFFER (default
    65536 events, ``REPRO_TRACE_BUFFER`` overrides) so long serving
    runs keep the last N events instead of growing without bound, and
    the buffer is flushed to ``path`` at process exit (or explicitly
    via ``get_tracer().save()`` / the CLIs' ``--trace-out``).

Durations measure wall time of the wrapped block.  JAX dispatch is
asynchronous — a span around a step call measures dispatch unless the
caller synchronizes; the serving engine reads every step's outputs
back to host (sampling), which makes its spans end-to-end in
practice.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager

DEFAULT_BUFFER_EVENTS = 65536


class _NullSpan:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class Tracer:
    """Ring-buffered Chrome-trace-event recorder."""

    def __init__(self, capacity: int = DEFAULT_BUFFER_EVENTS):
        self.enabled = False
        self.path: str | None = None
        self._events: deque = deque(maxlen=max(1, int(capacity)))
        self._lock = threading.Lock()
        self._pid = os.getpid()

    # -- control -------------------------------------------------------
    def enable(self, path: str | None = None,
               capacity: int | None = None):
        """Turn tracing on; ``path`` is where ``save()`` (and the
        atexit flush) writes."""
        if capacity is not None and capacity != self._events.maxlen:
            self._events = deque(self._events,
                                 maxlen=max(1, int(capacity)))
        self.enabled = True
        if path is not None:
            self.path = path
        return self

    def disable(self):
        self.enabled = False
        return self

    def clear(self):
        with self._lock:
            self._events.clear()

    def __len__(self):
        return len(self._events)

    # -- recording -----------------------------------------------------
    def span(self, name: str, **args):
        """Context manager recording one complete ("X") event around
        the block.  No-op (shared null CM) when disabled."""
        if not self.enabled:
            return _NULL
        return self._span(name, args)

    @contextmanager
    def _span(self, name, args):
        t0 = time.perf_counter_ns()
        try:
            yield None
        finally:
            t1 = time.perf_counter_ns()
            ev = {"name": name, "ph": "X", "ts": t0 // 1000,
                  "dur": max(0, (t1 - t0) // 1000), "pid": self._pid,
                  "tid": threading.get_ident() & 0xFFFFFFFF}
            if args:
                ev["args"] = args
            with self._lock:
                self._events.append(ev)

    def instant(self, name: str, **args):
        """One instant ("i") event — markers like preemptions."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t",
              "ts": time.perf_counter_ns() // 1000, "pid": self._pid,
              "tid": threading.get_ident() & 0xFFFFFFFF}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    # -- export --------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def save(self, path: str | None = None) -> str | None:
        """Write the buffered events as a Chrome-trace JSON array.
        Returns the path written, or None when there is nowhere to
        write."""
        path = path or self.path
        if path is None:
            return None
        with open(path, "w") as f:
            json.dump(self.events(), f)
        return path


_TRACER: Tracer | None = None
_ATEXIT_REGISTERED = False


def _buffer_capacity() -> int:
    env = os.environ.get("REPRO_TRACE_BUFFER", "").strip()
    try:
        return int(env) if env else DEFAULT_BUFFER_EVENTS
    except ValueError:
        return DEFAULT_BUFFER_EVENTS


def get_tracer() -> Tracer:
    """The process-wide tracer.  First call reads ``REPRO_TRACE``: a
    non-empty value enables tracing with that output path and
    registers an atexit flush."""
    global _TRACER, _ATEXIT_REGISTERED
    if _TRACER is None:
        _TRACER = Tracer(capacity=_buffer_capacity())
        env = os.environ.get("REPRO_TRACE", "").strip()
        if env:
            _TRACER.enable(path=env)
            if not _ATEXIT_REGISTERED:
                atexit.register(_flush_at_exit)
                _ATEXIT_REGISTERED = True
    return _TRACER


def _flush_at_exit():
    if _TRACER is not None and _TRACER.enabled and _TRACER.path:
        _TRACER.save()


def trace_enabled() -> bool:
    return get_tracer().enabled


def span(name: str, **args):
    """Module-level convenience: ``with span("decode", rows=4): ...``"""
    return get_tracer().span(name, **args)


def instant(name: str, **args):
    get_tracer().instant(name, **args)
