"""fp8 quantization-health telemetry (docs/observability.md).

MOSS's delayed/predicted scaling removed the per-step amax reductions
— and with them the only signal that would say when quantization goes
wrong.  Under ``REPRO_QUANT_HEALTH=1`` every delayed-activation GEMM
site reports, per engine step and per ``path_tag`` site key:

  - **saturation rate**   fraction of elements whose post-scale
                          magnitude exceeds the fp8 max (they clip at
                          ±448 for e4m3);
  - **underflow rate**    fraction of *nonzero* inputs that quantize
                          to exactly 0;
  - **drift ratio**       max over quantization groups of
                          ``live_amax_g / (scale_g · FP8_MAX)`` — the
                          live activation range relative to the edge
                          of the calibrated representable range.  The
                          calibration margin (default 1.25) means a
                          healthy site sits near ``1/margin`` ≈ 0.8; a
                          ratio above 1.0 means the live amax exceeds
                          calibrated × margin and values are clipping
                          → ``refresh_recommended`` flips on and
                          ``Engine.refresh_act_scales()`` is the fix.

Mechanics — and why telemetry off is FREE:

  - at step **build** time (``make_*_step``), and only when the flag
    is on, each site's ``ActScale`` is wrapped in a ``TaggedScale``
    carrying its site tag;
  - ``qlinear`` computes the site stats (pure element-wise compares +
    tiny reductions over the activation — no extra quant reductions:
    nothing here feeds an fp8 cast) and records the tracers into the
    module collector ``QH``;
  - ``transformer.forward``'s scan-over-layers body drains the
    collector each layer into the scan's ``ys`` slot, so per-site
    stats come out stacked ``(layers, ...)`` exactly like the
    ``ActScale``s ride in;
  - the step function returns the collected tree as an extra output
    and the engine feeds it to a host-side ``HealthAggregator`` that
    publishes registry histograms.

  With the flag off none of this exists: ``qlinear`` sees a plain
  ``ActScale``, the scan body's drain returns ``None`` (the ``ys``
  slot it always had), and the step returns its usual 2-tuple — the
  decode/verify jaxprs are byte-identical to an obs-free build
  (tests/test_obs.py).

Limitation: sites evaluated under ``jax.vmap`` (the per-expert MoE
FFN on the decode path) are skipped — their stats are vmap-trace
local and cannot escape through the layer scan.  Dense, attention and
head sites (the vast majority of GEMM traffic) are all covered.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.actscale import ActScale, effective_group_scales
from repro.core.formats import TINY, fp8_max
from repro.core.quant import quant_excursions

from .metrics import DRIFT_BUCKETS, RATE_BUCKETS, get_registry

DRIFT_THRESHOLD = 1.0      # live amax past calibrated × margin


def quant_health_enabled() -> bool:
    from repro.core.runtime_flags import quant_health

    return quant_health()


# ---------------------------------------------------------------------------
# TaggedScale: ActScale + site identity, attachable as QT.a
# ---------------------------------------------------------------------------


class TaggedScale:
    """An ``ActScale`` bundled with its ``path_tag`` site key — what
    ``_wrap_serve`` attaches instead of the bare ``ActScale`` when
    quant-health is on.  Registered as a pytree with the tag static,
    so ``lax.scan`` slices the scale arrays per layer while the tag
    rides along untouched."""

    __slots__ = ("tag", "scale")

    def __init__(self, tag: str, scale: ActScale):
        self.tag = tag
        self.scale = scale

    def __repr__(self):
        return f"TaggedScale({self.tag!r})"


jax.tree_util.register_pytree_node(
    TaggedScale,
    lambda t: ((t.scale,), t.tag),
    lambda tag, children: TaggedScale(tag, children[0]),
)


def tag_act_scales(act: dict | None) -> dict | None:
    """{tag: ActScale} -> {tag: TaggedScale} (build-time, flag on)."""
    if act is None:
        return None
    return {tag: TaggedScale(tag, a) for tag, a in act.items()}


# ---------------------------------------------------------------------------
# In-graph site statistics
# ---------------------------------------------------------------------------


def site_stats(x: jax.Array, a: ActScale, cfg) -> dict[str, jax.Array]:
    """Quantization-health statistics for one GEMM site's activation
    ``x`` (inner dim last) against its calibrated ``ActScale``.

    Pure element-wise compares plus small reductions over ``x`` — no
    value here ever feeds an fp8 cast, so
    ``core.introspect.count_quant_reductions`` stays 0 even with
    telemetry on.  Returns f32 scalars (counts/max) that stack to
    ``(layers,)`` through the forward's scan."""
    fmax = float(fp8_max(cfg.fwd_format))
    k = x.shape[-1]
    x2d = jnp.abs(x.astype(jnp.float32).reshape(-1, k))
    sg, g = effective_group_scales(a, cfg, k)
    pad = (-k) % g
    if pad:
        x2d = jnp.pad(x2d, ((0, 0), (0, pad)))
    xg = x2d.reshape(x2d.shape[0], -1, g)
    sat, under, nonzero = quant_excursions(xg, sg[None, :, None],
                                           cfg.fwd_format)
    # per-group live amax first, then the (tiny) per-group ratios —
    # same max as a full-size ratio array at a fraction of the work
    ag = jnp.max(xg, axis=(0, 2))
    return {
        "n": jnp.float32(x2d.shape[0] * k),     # real (unpadded) count
        "sat": sat,
        "underflow": under,
        "nonzero": nonzero,
        "amax": jnp.max(ag),
        "drift": jnp.max(ag / jnp.maximum(sg, TINY)) / fmax,
    }


# ---------------------------------------------------------------------------
# Trace-time collector
# ---------------------------------------------------------------------------


def _under_vmap(x) -> bool:
    """True when ``x`` is a vmap batch tracer — its stats could not
    escape through the layer scan (see module docstring)."""
    try:
        from jax.interpreters.batching import BatchTracer

        return isinstance(x, BatchTracer)
    except ImportError:                       # pragma: no cover
        return type(x).__name__ == "BatchTracer"


class _Capture:
    """Result box for one ``QH.capture()`` window."""

    def __init__(self):
        self.tree: dict[str, dict] = {}


class _Collector:
    """Module-level tap sink: ``qlinear`` records tracer stats here
    while a health-enabled step function is being traced, the layer
    scan drains per layer, and the step function collects the merged
    tree as an extra output.  ``tracing`` is False outside a capture
    window, making every tap a no-op."""

    def __init__(self):
        self.tracing = False
        self._sink: dict[str, dict] = {}
        self._stacked: dict[str, dict] = {}

    def record(self, tag: str, x, a: ActScale, cfg) -> None:
        if not self.tracing or _under_vmap(x):
            return
        self._sink[tag] = site_stats(x, a, cfg)

    def drain_layer(self) -> dict | None:
        """Called by the forward's scan body: this layer's recorded
        stats become the scan's per-iteration ``ys`` output (stacked
        over layers by scan itself).  Returns None — the slot's
        historical value, leaving the jaxpr untouched — when no
        capture window is open or nothing was recorded."""
        if not self.tracing or not self._sink:
            return None
        out, self._sink = self._sink, {}
        return out

    def stash_stacked(self, tree) -> None:
        """Called by the forward after a scan: adopt the (layers, ...)
        stacked ys tree."""
        if tree:
            self._stacked.update(tree)

    @contextlib.contextmanager
    def capture(self):
        """Open a collection window around a forward call (inside the
        step function being traced).  Yields a ``_Capture`` whose
        ``tree`` is the flat ``{site tag: {stat: array}}`` dict after
        the window closes — scan-stacked sites carry a leading
        ``(layers,)`` dim, top-level sites (the LM head) are scalars."""
        prev = (self.tracing, self._sink, self._stacked)
        self.tracing, self._sink, self._stacked = True, {}, {}
        cap = _Capture()
        try:
            yield cap
        finally:
            cap.tree = dict(self._stacked)
            cap.tree.update(self._sink)       # top-level (unscanned)
            self.tracing, self._sink, self._stacked = prev


QH = _Collector()


# ---------------------------------------------------------------------------
# Host-side aggregation -> registry
# ---------------------------------------------------------------------------


class HealthAggregator:
    """Consumes the per-step health trees the engine pulls off device
    and publishes them: per-site saturation/underflow-rate and
    drift-ratio histograms in the metrics registry, plus the
    ``refresh_recommended`` flag once any site's drift exceeds the
    threshold (live amax beyond calibrated × margin)."""

    def __init__(self, registry=None,
                 drift_threshold: float = DRIFT_THRESHOLD):
        self.reg = registry or get_registry()
        self.drift_threshold = float(drift_threshold)
        self.sites: dict[str, dict] = {}
        self.steps = 0
        self.refresh_recommended = False
        self._h_sat = self.reg.histogram(
            "quant_health_saturation_rate", buckets=RATE_BUCKETS,
            help="per-site fraction of activations clipping at fp8 max")
        self._h_under = self.reg.histogram(
            "quant_health_underflow_rate", buckets=RATE_BUCKETS,
            help="per-site fraction of nonzero activations quantizing "
                 "to 0")
        self._h_drift = self.reg.histogram(
            "quant_health_drift_ratio", buckets=DRIFT_BUCKETS,
            help="per-site live-amax / calibrated-range ratio "
                 "(>1 = clipping)")
        self._g_flag = self.reg.gauge(
            "quant_health_refresh_recommended",
            help="1 once any site's drift ratio exceeded the "
                 "threshold — call Engine.refresh_act_scales()")
        self._g_flag.set(0.0)

    def ingest(self, tree: dict[str, dict[str, Any]]) -> None:
        """One step's ``{site tag: stats}`` tree (device arrays or
        numpy).  Counts are summed over the stacked layer dim, drift
        is maxed — a single bad layer should trip the flag."""
        if not tree:
            return
        tree = jax.device_get(tree)
        self.steps += 1
        for tag, st in tree.items():
            n = float(np.sum(st["n"]))
            sat = float(np.sum(st["sat"]))
            nonzero = float(np.sum(st["nonzero"]))
            under = float(np.sum(st["underflow"]))
            amax = float(np.max(st["amax"]))
            drift = float(np.max(st["drift"]))
            sat_rate = sat / max(n, 1.0)
            under_rate = under / max(nonzero, 1.0)
            lab = {"site": tag}
            self._h_sat.observe(sat_rate, labels=lab)
            self._h_under.observe(under_rate, labels=lab)
            self._h_drift.observe(drift, labels=lab)
            s = self.sites.setdefault(tag, {
                "n": 0.0, "sat": 0.0, "nonzero": 0.0, "underflow": 0.0,
                "amax": 0.0, "drift_max": 0.0, "steps": 0})
            s["n"] += n
            s["sat"] += sat
            s["nonzero"] += nonzero
            s["underflow"] += under
            s["amax"] = max(s["amax"], amax)
            s["drift_max"] = max(s["drift_max"], drift)
            s["steps"] += 1
            if drift > self.drift_threshold:
                self.refresh_recommended = True
                self._g_flag.set(1.0)

    def report(self) -> dict:
        """Per-site summary rates (for ``Engine.stats()`` / tests)."""
        out = {}
        for tag, s in self.sites.items():
            out[tag] = {
                "saturation_rate": s["sat"] / max(s["n"], 1.0),
                "underflow_rate": s["underflow"] / max(s["nonzero"],
                                                       1.0),
                "drift_max": s["drift_max"],
                "amax": s["amax"],
                "steps": s["steps"],
            }
        return out
