"""Unified observability layer (docs/observability.md).

Three independent pillars, all free when off:

  - ``obs.metrics``      process-wide metrics registry (counters,
                         gauges, histograms) + Prometheus-text / JSON
                         exporters — the single export surface for the
                         scattered serving stats (``Scheduler.summary``,
                         ``Engine.stats``, ``PageAllocator`` counters);
  - ``obs.trace``        span API emitting Chrome-trace-event JSON
                         (Perfetto-viewable), ring-buffered, env-gated
                         by ``REPRO_TRACE=path``;
  - ``obs.quant_health`` fp8 quantization-health telemetry
                         (saturation / underflow / ActScale drift per
                         GEMM site), env-gated by
                         ``REPRO_QUANT_HEALTH=1``.

The hard contract: with both gates off, the serving jaxprs are
byte-identical to an obs-free build and contain zero quantization
reductions (tests/test_obs.py asserts this via ``core.introspect``).
"""

from .metrics import Registry, get_registry
from .trace import get_tracer, span, trace_enabled

__all__ = ["Registry", "get_registry", "get_tracer", "span",
           "trace_enabled"]
