"""Process-wide metrics registry (docs/observability.md).

One ``Registry`` instance per process (``get_registry``) unifies the
serving stats that historically lived on scattered objects —
``Scheduler.summary()``, ``Engine.stats()``, the ``PageAllocator``
occupancy/eviction/CoW counters — behind a single export surface:

  - ``Counter``    monotonically increasing float (events)
  - ``Gauge``      last-write-wins float (occupancy, rates)
  - ``Histogram``  fixed bucket boundaries, cumulative counts + sum
                   (latency / rate distributions)

Every instrument supports an optional flat ``labels`` dict (e.g.
``{"site": "blocks/attn/wq"}``); each distinct label set is an
independent series.  ``snapshot()`` returns a plain-JSON-serializable
dict (never NaN/Inf — those serialize as invalid JSON; see the
``Scheduler.summary`` fix this PR rode in with), and
``to_prometheus()`` renders the standard text exposition format.

Host-side and dependency-free by design: nothing here touches jax, so
publishing metrics can never perturb a traced graph.
"""

from __future__ import annotations

import json
import math
import threading

# Default latency buckets (seconds): 1ms .. 60s, roughly log-spaced.
LATENCY_BUCKETS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                     0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)
# Default rate buckets (dimensionless fractions in [0, 1]): tuned for
# the quant-health saturation/underflow rates, where "a few ppm" and
# "a few percent" are the interesting regimes.
RATE_BUCKETS = (0.0, 1e-6, 1e-5, 1e-4, 1e-3, 0.01, 0.05, 0.1, 0.25,
                0.5, 1.0)
# Default drift-ratio buckets: 1.0 is the refresh threshold (live amax
# at the edge of the calibrated representable range).
DRIFT_BUCKETS = (0.1, 0.25, 0.5, 0.75, 0.9, 1.0, 1.25, 1.5, 2.0, 4.0,
                 8.0)


def _label_key(labels: dict | None) -> tuple:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def _finite(v: float) -> float | None:
    """None for NaN/Inf — the JSON-safety choke point."""
    v = float(v)
    return v if math.isfinite(v) else None


class _Metric:
    """Base: one named metric with per-label-set series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[tuple, object] = {}

    def _series_for(self, labels: dict | None):
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = self._new_series()
        return s

    def labelsets(self):
        return list(self._series)


class Counter(_Metric):
    kind = "counter"

    def _new_series(self):
        return [0.0]

    def inc(self, value: float = 1.0, labels: dict | None = None):
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        self._series_for(labels)[0] += value

    def set_total(self, value: float, labels: dict | None = None):
        """Adopt an externally-kept running total (how the engine's
        pre-registry int fields publish without double counting)."""
        s = self._series_for(labels)
        s[0] = max(s[0], float(value))

    def value(self, labels: dict | None = None) -> float:
        return self._series_for(labels)[0]

    def _snap(self, series):
        return _finite(series[0])


class Gauge(_Metric):
    kind = "gauge"

    def _new_series(self):
        return [0.0]

    def set(self, value: float, labels: dict | None = None):
        self._series_for(labels)[0] = float(value)

    def value(self, labels: dict | None = None) -> float:
        return self._series_for(labels)[0]

    def _snap(self, series):
        return _finite(series[0])


class Histogram(_Metric):
    """Fixed-boundary histogram: counts per bucket (cumulative in the
    Prometheus export, per-bucket in ``snapshot``), plus sum/count."""

    kind = "histogram"

    def __init__(self, name: str, buckets, help: str = ""):
        super().__init__(name, help)
        b = tuple(float(x) for x in buckets)
        if list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError(
                f"histogram {name}: buckets must be strictly increasing")
        self.buckets = b

    def _new_series(self):
        # [counts per bucket..., overflow, sum, count]
        return [0.0] * (len(self.buckets) + 1) + [0.0, 0.0]

    def observe(self, value: float, labels: dict | None = None):
        v = float(value)
        if math.isnan(v):
            return
        s = self._series_for(labels)
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                s[i] += 1
                break
        else:
            s[len(self.buckets)] += 1
        s[-2] += v
        s[-1] += 1

    def _snap(self, series):
        nb = len(self.buckets)
        return {
            "buckets": list(self.buckets),
            "counts": [int(c) for c in series[:nb + 1]],
            "sum": _finite(series[-2]),
            "count": int(series[-1]),
        }


class Registry:
    """Name -> metric map with get-or-create constructors.

    Re-declaring a name returns the existing instrument (so modules can
    declare at use sites without coordinating), but a kind mismatch is
    a hard error — two subsystems fighting over one name is a bug.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as "
                        f"{m.kind}, requested {cls.kind}")
                return m
            m = self._metrics[name] = cls(name, help=help, **kw)
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, buckets=LATENCY_BUCKETS_S,
                  help: str = "") -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def reset(self):
        """Drop every metric (tests / between benchmark phases)."""
        with self._lock:
            self._metrics.clear()

    # -- export --------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain dict of every series — JSON-safe by construction
        (non-finite values become null)."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = {_label_str(k) or "": m._snap(s)
                      for k, s in sorted(m._series.items())}
            out[name] = {"kind": m.kind, "help": m.help,
                         "series": series}
        return out

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(), indent=indent,
                          allow_nan=False)

    def to_prometheus(self) -> str:
        """Standard Prometheus text exposition format."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, s in sorted(m._series.items()):
                if isinstance(m, Histogram):
                    cum = 0.0
                    for edge, c in zip(m.buckets, s):
                        cum += c
                        le = (f"{edge:g}" if math.isfinite(edge)
                              else "+Inf")
                        lk = key + (("le", le),)
                        lines.append(
                            f"{name}_bucket{_label_str(lk)} {cum:g}")
                    cum += s[len(m.buckets)]
                    lk = key + (("le", "+Inf"),)
                    lines.append(f"{name}_bucket{_label_str(lk)} {cum:g}")
                    lines.append(f"{name}_sum{_label_str(key)} {s[-2]:g}")
                    lines.append(
                        f"{name}_count{_label_str(key)} {s[-1]:g}")
                else:
                    lines.append(f"{name}{_label_str(key)} {s[0]:g}")
        return "\n".join(lines) + "\n"


_REGISTRY = Registry()


def get_registry() -> Registry:
    """The process-wide default registry."""
    return _REGISTRY
