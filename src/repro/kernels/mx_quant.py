"""Pallas TPU kernel: fused two-level microscaling quantizer
(paper Eqs. 2-3).

Per (bm, bk) tile: group amaxes over 32-wide micro-groups, E8M0
exponents relative to the (precomputed) level-1 global scale, and the
saturating E4M3/E5M2 cast — one HBM read of the bf16/f32 activation, one
fp8 write + one int8 exponent write.  This is the fusion that replaces
just-in-time scaling's multiple passes (paper §3.2's memory-traffic
argument applied to the activation path).

The global scale s = max_g(amax_g)/FP8_MAX needs a full reduction, so it
is computed OUTSIDE (one fused jnp.max) and passed in as a (1, 1) f32
operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat.jaxapi import pallas_tpu_compiler_params
from repro.core.formats import E4M3_MAX, E5M2_MAX

MICRO = 32
_TINY = 1e-30


def _mx_quant_kernel(x_ref, s_ref, q_ref, se_ref, *, fp8_max: float,
                     out_dtype):
    x = x_ref[...].astype(jnp.float32)                    # (bm, bk)
    bm, bk = x.shape
    s = jnp.maximum(s_ref[0, 0], _TINY)
    xg = x.reshape(bm, bk // MICRO, MICRO)
    amax = jnp.max(jnp.abs(xg), axis=-1)                  # (bm, bk/32)
    s_g = amax / fp8_max
    e = jnp.ceil(jnp.log2(jnp.maximum(s_g / s, 2.0 ** -149)) - 1e-6)
    e = jnp.clip(e, -127, 127)
    se_ref[...] = e.astype(jnp.int8)
    denom = jnp.exp2(e) * s
    safe = jnp.where(denom > 0, denom, 1.0)[..., None]
    q = jnp.where(denom[..., None] > 0, xg / safe, 0.0)
    q = jnp.clip(q, -fp8_max, fp8_max)
    q_ref[...] = q.reshape(bm, bk).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("fmt", "bm", "bk",
                                             "interpret"))
def mx_quant_pallas(x, s_global, *, fmt: str = "e4m3", bm: int = 256,
                    bk: int = 512, interpret: bool = False):
    """x: (M, K); s_global: () f32.  Returns (q fp8 (M,K), sexp int8
    (M, K//32))."""
    m, k = x.shape
    assert k % MICRO == 0
    bm, bk = min(bm, m), min(bk, k)
    assert m % bm == 0 and k % bk == 0 and bk % MICRO == 0
    fp8_max = E4M3_MAX if fmt == "e4m3" else E5M2_MAX
    out_dtype = jnp.float8_e4m3fn if fmt == "e4m3" else jnp.float8_e5m2
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        functools.partial(_mx_quant_kernel, fp8_max=fp8_max,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // MICRO), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), out_dtype),
            jax.ShapeDtypeStruct((m, k // MICRO), jnp.int8),
        ],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
    )(x, s_global.reshape(1, 1))
