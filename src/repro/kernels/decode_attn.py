"""Pallas TPU kernel: fused decode attention over the (fp8 | bf16) KV
cache — the serving hot path after weight pre-quantization.

The einsum decode path dequantizes the fp8 cache *structurally*: XLA
upcasts the whole e4m3 K and V payloads to bf16/f32 to feed the MXU
(two full-cache ``convert_element_type`` ops per layer per step), folds
the per-(token, kv-head) scales into the scores / combine weights with
separate broadcast multiplies, and runs the masked softmax as its own
fusion.  This kernel collapses all of it into ONE launch per
(batch, kv-head) cell:

  read e4m3 payload → upcast in VMEM → Q·Kᵀ → ×k_scale → ring-validity
  mask → softmax → ×v_scale → ·V → out

so the cache crosses HBM exactly once, at 1 byte/element, and nothing
cache-sized is ever materialized in HBM (``core/introspect.py`` counts
the removed upcasts/dots on the decode jaxpr).  A bf16 cache takes the
same kernel with the scale operands elided — one entry point for both
cache dtypes.

Operand contract (see docs/decode-attention.md)
-----------------------------------------------
  q         (B, KV, R, Dh)  f32/bf16 — queries grouped by kv head,
                            R = q_len · Gp rows: ``q_len`` queries
                            (draft-major) of Gp heads each (GQA:
                            G = n_heads // n_kv; dispatch pads G up to
                            the 8-row sublane tile).  q_len == 1 is
                            plain decode; q_len == k is the
                            speculative verify step — k draft queries
                            share ONE cache read
  k, v      (B, KV, C, Dh)  e4m3 or bf16 payloads — the cache layout
                            itself (kv-head-major), read in place
  k_scale,  (B, KV, C)      f32 per-(token, kv-head) scales; None for
  v_scale                   the bf16 cache
  n_valid   (B,)            int32 scalar-prefetch (SMEM): per-batch
                            absolute positions written so far AFTER
                            this step's q_len-token write (the
                            per-slot cache ``idx`` of the continuous-
                            batching engine — docs/continuous-
                            batching.md); each entry must be ≥ q_len
                            (decode attends after a write).  A scalar
                            (shared-ring legacy cache) broadcasts to
                            (B,) at dispatch.  For draft j of batch
                            row b (j = row // Gp), slot s is valid iff
                            s < min(n_valid[b] - (q_len-1-j), C) — the
                            in-step causal mask between drafts; at
                            q_len == 1 this reduces to the ring rule
                            s < min(n_valid[b], C) (a wrapped cache,
                            idx ≥ C, is fully valid; slot order is
                            irrelevant to softmax).  q_len > 1
                            requires an unwrapped cache
                            (n_valid ≤ C): rejection-truncation
                            semantics are undefined on a ring
  returns   (B, KV, R, Dh)  f32 UNCAST attention output

Grid is (B, KV, C/bc) — the third axis is the split-K dimension over
the context.  With one C block (``bc == C``, the common serving case)
the kernel computes the exact masked softmax in the same operation
order as the einsum path — bitwise-identical on a bf16 cache
(tests/test_decode_attn.py).  With several C blocks (C above the
MAX_SINGLE_BLOCK VMEM ceiling, or an explicit ``bc``) it switches to
revisiting-free online (flash) rescaling — each C block is visited
exactly once, m/l/acc carry across grid steps in VMEM scratch — which
matches to f32 round-off.

Alignment is CALLER-owned only for G (pad to ≥ 8 rows); C and Dh are
taken as-is — the trailing partial C block is masked in-kernel (scores
to NEG_INF, garbage V rows zeroed) so the cache is never padded or
copied in HBM.

Floating-page variant (``decode_attn_paged_pallas``)
----------------------------------------------------
The serving engine's floating-page pool (docs/paged-attention.md)
stores K/V as ``(P, KV, T, Dh)`` — P physical pages of T tokens each,
shared by every slot — and a per-slot block table maps logical page j
of batch row b to an arbitrary physical row.  The block table rides in
as a SECOND scalar-prefetch operand ``(B, pages_per_slot) int32``
right after ``n_valid``, and the K/V/scale index maps read it:

  block index (bi, ki, pi)  ->  (block_table[bi, pi], ki, 0, 0)

so the gather happens in the DMA schedule — each grid step streams one
physical ``(T, Dh)`` page tile into VMEM and nothing cache-sized is
ever copied or materialized contiguously in HBM.  Grid is
(B, KV, pages_per_slot).  Up to C = MAX_SINGLE_BLOCK, per-page scores /
V tiles / v_scales accumulate into VMEM scratch and the LAST page step
runs the exact masked softmax in the same operation order as the
contiguous single-block path above, so paged-vs-contiguous decode is
bitwise-identical given identical page contents
(tests/test_paged_attn.py).  Past that ceiling the gathered (R, C) /
(C, Dh) scratch no longer fits, so the kernel switches to the same
revisiting-free online-softmax accumulation as the contiguous
multi-block path (one C block == one page), keeping long contexts
VMEM-resident page by page with no cache copy — matching the exact
path to f32 round-off.  Both kernels take the same ``q_len`` batched-
query extension (see operand contract above).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.jaxapi import pallas_tpu_compiler_params

NEG_INF = -1e30
_TINY = 1e-30

# single-block VMEM budget: one (bc, Dh) K block + V block (fp8) plus
# their f32 upcasts stay well under the ~16 MB/core VMEM at Dh=128
MAX_SINGLE_BLOCK = 2048
MULTI_BLOCK = 1024


def _decode_attn_kernel(nv_ref, q_ref, k_ref, v_ref, *rest, n_c: int,
                        bc: int, c_true: int, sm_scale: float,
                        quantized: bool, op_dtype, q_len: int, gp: int):
    if quantized:
        ks_ref, vs_ref, o_ref = rest[:3]
        scratch = rest[3:]
    else:
        o_ref = rest[0]
        scratch = rest[1:]
    ci = pl.program_id(2)

    # operands mirror runtime_flags.mm: bf16 values (fp8 casts are
    # exact in bf16), f32 accumulation — bf16 on the MXU, f32 under the
    # CPU interpreter, so interpret-vs-ref parity is bitwise
    q = q_ref[0, 0].astype(jnp.bfloat16).astype(op_dtype)     # (R, Dh)
    k = k_ref[0, 0].astype(jnp.bfloat16).astype(op_dtype)     # (bc, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                          # (R, bc)
    if quantized:
        # fold the per-(token, kv-head) K scale into the score — the
        # payload itself is never dequantized in HBM
        s = s * ks_ref[0, 0][None, :]

    # ring-validity mask: slot < min(n_valid[b], C) covers the partial
    # ring (idx < C), the fully-wrapped ring (all C slots valid) and
    # the trailing partial block (slots ≥ C).  n_valid is per batch
    # row — slots at different depths coexist in one decode batch
    # (the continuous-batching engine's per-slot length vector).
    slot = ci * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    nv = jnp.minimum(nv_ref[pl.program_id(0)], c_true)
    col_valid = slot < nv                                     # (1, bc)
    if q_len == 1:
        valid = col_valid
    else:
        # in-step causal mask between drafts: row r holds draft
        # j = r // Gp, whose query position is n_valid[b]-q_len+j, so
        # it may attend slots < n_valid[b] - (q_len-1-j) — including
        # its OWN freshly-written K at position n_valid[b]-q_len+j
        draft = jax.lax.broadcasted_iota(
            jnp.int32, (q_len * gp, 1), 0) // gp
        lim = jnp.minimum(
            nv_ref[pl.program_id(0)] - (q_len - 1 - draft), c_true)
        valid = slot < lim                                    # (R, bc)
    s = jnp.where(valid, s, NEG_INF)

    v = v_ref[0, 0].astype(jnp.bfloat16).astype(op_dtype)     # (bc, Dh)

    if n_c == 1:
        # exact masked softmax, same operation order as the einsum
        # reference (max → exp → sum → divide → ×v_scale → dot): on a
        # bf16 cache the result is bitwise-identical to the ref path
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        w = p / jnp.sum(p, axis=-1, keepdims=True)
        if quantized:
            w = w * vs_ref[0, 0][None, :]
        o_ref[0, 0] = jax.lax.dot_general(
            w.astype(jnp.bfloat16).astype(op_dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return

    # multi-block: online (flash) softmax across C blocks.  The
    # trailing partial block may hold garbage V rows (Pallas pads the
    # edge); their weights are exactly 0 but 0·NaN would poison, so
    # zero them explicitly.  Zeroing keys off COLUMN validity (the
    # widest draft's window): a column a stricter draft row masks
    # contributes exp-underflowed exact 0 × finite V = 0 to that row.
    v = jnp.where(col_valid.reshape(bc, 1), v, 0.0)
    m_ref, l_ref, acc_ref = scratch

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m_prev = m_ref[:, :1]                                     # (Gp, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                    # (Gp, bc)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    if quantized:
        # re-mask after the scale fold: a garbage-padded v_scale is
        # NaN under the interpreter and 0 · NaN would poison the dot
        p = jnp.where(valid, p * vs_ref[0, 0][None, :], 0.0)
    pv = jax.lax.dot_general(p.astype(jnp.bfloat16).astype(op_dtype), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ci == n_c - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[:, :1], _TINY)


@functools.partial(jax.jit,
                   static_argnames=("sm_scale", "bc", "interpret",
                                    "q_len"))
def decode_attn_pallas(q, k, v, k_scale, v_scale, n_valid, *,
                       sm_scale: float, bc: int | None = None,
                       interpret: bool = False, q_len: int = 1):
    """q: (B, KV, R, Dh) with R = q_len·Gp, Gp % 8 == 0 (dispatch
    pads); k/v: (B, KV, C, Dh) e4m3|bf16 payloads; k_scale/v_scale:
    (B, KV, C) f32 or both None (bf16 cache); n_valid: (B,) int32
    scalar-prefetch — per-slot valid counts AFTER this step's write (a
    (1,) value broadcasts to every row); every entry must be ≥ q_len.
    Returns (B, KV, R, Dh) f32.  ``bc`` picks the C block: defaults
    to one block (exact softmax) up to MAX_SINGLE_BLOCK, else the
    online multi-block (split-K) path.  ``q_len`` > 1 is the
    speculative verify step: draft-major query rows under the in-step
    causal mask (see module docstring)."""
    from repro.core.runtime_flags import mm_operand_dtype

    b, kvh, rows, dh = q.shape
    c = k.shape[2]
    assert k.shape == v.shape == (b, kvh, c, dh), (q.shape, k.shape)
    assert rows % q_len == 0, (rows, q_len)
    gp = rows // q_len
    assert gp % 8 == 0, f"G={gp} not padded to the 8-row sublane tile"
    quantized = k_scale is not None
    if quantized:
        assert k_scale.shape == v_scale.shape == (b, kvh, c)
    if bc is None:
        bc = c if c <= MAX_SINGLE_BLOCK else MULTI_BLOCK
    bc = min(bc, c)
    n_c = pl.cdiv(c, bc)
    grid = (b, kvh, n_c)

    in_specs = [
        pl.BlockSpec((1, 1, rows, dh),
                     lambda bi, ki, ci, nv: (bi, ki, 0, 0)),
        pl.BlockSpec((1, 1, bc, dh), lambda bi, ki, ci, nv: (bi, ki, ci, 0)),
        pl.BlockSpec((1, 1, bc, dh), lambda bi, ki, ci, nv: (bi, ki, ci, 0)),
    ]
    args = [q, k, v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, bc), lambda bi, ki, ci, nv: (bi, ki, ci)),
            pl.BlockSpec((1, 1, bc), lambda bi, ki, ci, nv: (bi, ki, ci)),
        ]
        args += [k_scale, v_scale]
    scratch = [] if n_c == 1 else [
        pltpu.VMEM((rows, 128), jnp.float32),    # running max (col 0)
        pltpu.VMEM((rows, 128), jnp.float32),    # running sum (col 0)
        pltpu.VMEM((rows, dh), jnp.float32),     # output accumulator
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rows, dh),
                               lambda bi, ki, ci, nv: (bi, ki, 0, 0)),
        scratch_shapes=scratch,
    )
    nv = jnp.broadcast_to(n_valid.astype(jnp.int32).reshape(-1), (b,))
    return pl.pallas_call(
        functools.partial(_decode_attn_kernel, n_c=n_c, bc=bc, c_true=c,
                          sm_scale=sm_scale, quantized=quantized,
                          op_dtype=mm_operand_dtype(), q_len=q_len,
                          gp=gp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rows, dh), jnp.float32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(nv, *args)


def _paged_decode_kernel(nv_ref, bt_ref, q_ref, k_ref, v_ref, *rest,
                         n_p: int, t: int, sm_scale: float,
                         quantized: bool, op_dtype, q_len: int,
                         gp: int, online: bool):
    if quantized:
        ks_ref, vs_ref, o_ref = rest[:3]
        scratch = rest[3:]
    else:
        o_ref = rest[0]
        scratch = rest[1:]
    del bt_ref          # consumed by the index maps, not the body
    pi = pl.program_id(2)
    c_true = n_p * t

    # identical operand casts / op order to the contiguous single-block
    # kernel: bf16 values (fp8 casts are exact in bf16), f32 accumulation
    q = q_ref[0, 0].astype(jnp.bfloat16).astype(op_dtype)     # (R, Dh)
    k = k_ref[0, 0].astype(jnp.bfloat16).astype(op_dtype)     # (t, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                          # (R, t)
    if quantized:
        s = s * ks_ref[0, 0][None, :]

    # validity: logical slot pi*T + o of row b is live iff it is below
    # min(n_valid[b], C) — per DRAFT row when q_len > 1 (the in-step
    # causal mask, see module docstring).  Pages past the frontier hold
    # zeros (fresh pool) or a retired request's stale-but-finite values
    # — masked scores underflow to weight 0 exactly, and V rows /
    # v_scales are zeroed so the ref oracle's 0·finite contributions
    # match bitwise.
    slot = pi * t + jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    nv = jnp.minimum(nv_ref[pl.program_id(0)], c_true)
    col_valid = slot < nv                                     # (1, t)
    if q_len == 1:
        valid = col_valid
    else:
        draft = jax.lax.broadcasted_iota(
            jnp.int32, (q_len * gp, 1), 0) // gp
        lim = jnp.minimum(
            nv_ref[pl.program_id(0)] - (q_len - 1 - draft), c_true)
        valid = slot < lim                                    # (R, t)
    s = jnp.where(valid, s, NEG_INF)
    v = v_ref[0, 0].astype(jnp.float32)                       # (t, Dh)
    v = jnp.where(col_valid.reshape(t, 1), v, 0.0)

    if not online:
        if quantized:
            s_acc, v_acc, vs_acc = scratch
        else:
            s_acc, v_acc = scratch
        # stream this page's columns into the (R, C) / (C, Dh) scratch;
        # every column is freshly written once per (bi, ki) sweep, so
        # no init step is needed
        s_acc[:, pl.ds(pi * t, t)] = s
        v_acc[pl.ds(pi * t, t), :] = v
        if quantized:
            vs = jnp.where(col_valid, vs_ref[0, 0][None, :], 0.0)
            vs_acc[:, pl.ds(pi * t, t)] = jnp.broadcast_to(
                vs, (vs_acc.shape[0], t))

        @pl.when(pi == n_p - 1)
        def _done():
            # exact masked softmax over the gathered row, same operation
            # order as the single-block kernel and the einsum reference
            # (max -> exp -> sum -> divide -> ×v_scale -> dot)
            s_full = s_acc[...]
            m = jnp.max(s_full, axis=-1, keepdims=True)
            p = jnp.exp(s_full - m)
            w = p / jnp.sum(p, axis=-1, keepdims=True)
            if quantized:
                w = w * vs_acc[:1, :]
            o_ref[0, 0] = jax.lax.dot_general(
                w.astype(jnp.bfloat16).astype(op_dtype),
                v_acc[...].astype(op_dtype),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        return

    # split-K long-context path: C exceeds the gathered-scratch VMEM
    # ceiling, so accumulate online (flash) across pages instead —
    # one page per grid step, never revisited, mirroring the contiguous
    # multi-block path op for op (one C block == one page)
    m_ref, l_ref, acc_ref = scratch

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m_prev = m_ref[:, :1]                                     # (R, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                    # (R, t)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    if quantized:
        # re-mask after the scale fold: a garbage-padded v_scale is
        # NaN under the interpreter and 0 · NaN would poison the dot
        p = jnp.where(valid, p * vs_ref[0, 0][None, :], 0.0)
    pv = jax.lax.dot_general(
        p.astype(jnp.bfloat16).astype(op_dtype), v.astype(op_dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(pi == n_p - 1)
    def _done_online():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[:, :1], _TINY)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret",
                                             "q_len"))
def decode_attn_paged_pallas(q, k, v, k_scale, v_scale, n_valid,
                             block_table, *, sm_scale: float,
                             interpret: bool = False, q_len: int = 1):
    """Fused decode attention over the floating-page pool.

    q: (B, KV, R, Dh) with R = q_len·Gp, Gp % 8 == 0 (dispatch pads);
    k/v: (P, KV, T, Dh) e4m3|bf16 page-pool payloads; k_scale/v_scale:
    (P, KV, T) f32 or both None (bf16 cache); n_valid: (B,) int32 and
    block_table: (B, pages_per_slot) int32 — BOTH scalar-prefetch
    (SMEM), in that order.  Logical tokens [j*T, (j+1)*T) of row b
    live in physical page block_table[b, j]; the index maps gather
    them page tile by page tile (see module docstring).  Up to
    C = MAX_SINGLE_BLOCK the gathered exact-softmax path runs; past it
    the online split-K path (f32 round-off vs the oracle).  ``q_len``
    > 1 is the speculative verify step (draft-major rows, in-step
    causal mask; every n_valid entry must be ≥ q_len).  Returns
    (B, KV, R, Dh) f32."""
    from repro.core.runtime_flags import mm_operand_dtype

    b, kvh, rows, dh = q.shape
    p_pool, kvh_k, t = k.shape[:3]
    assert k.shape == v.shape == (p_pool, kvh, t, dh), (q.shape, k.shape)
    assert rows % q_len == 0, (rows, q_len)
    gp = rows // q_len
    assert gp % 8 == 0, f"G={gp} not padded to the 8-row sublane tile"
    n_p = block_table.shape[1]
    assert block_table.shape == (b, n_p)
    quantized = k_scale is not None
    if quantized:
        assert k_scale.shape == v_scale.shape == (p_pool, kvh, t)
    c_true = n_p * t
    online = c_true > MAX_SINGLE_BLOCK
    grid = (b, kvh, n_p)

    in_specs = [
        pl.BlockSpec((1, 1, rows, dh),
                     lambda bi, ki, pi, nv, bt: (bi, ki, 0, 0)),
        pl.BlockSpec((1, 1, t, dh),
                     lambda bi, ki, pi, nv, bt: (bt[bi, pi], ki, 0, 0)),
        pl.BlockSpec((1, 1, t, dh),
                     lambda bi, ki, pi, nv, bt: (bt[bi, pi], ki, 0, 0)),
    ]
    args = [q, k, v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, t),
                         lambda bi, ki, pi, nv, bt: (bt[bi, pi], ki, 0)),
            pl.BlockSpec((1, 1, t),
                         lambda bi, ki, pi, nv, bt: (bt[bi, pi], ki, 0)),
        ]
        args += [k_scale, v_scale]
    if online:
        scratch = [
            pltpu.VMEM((rows, 128), jnp.float32),  # running max (col 0)
            pltpu.VMEM((rows, 128), jnp.float32),  # running sum (col 0)
            pltpu.VMEM((rows, dh), jnp.float32),   # output accumulator
        ]
    else:
        scratch = [
            pltpu.VMEM((rows, c_true), jnp.float32),  # gathered scores
            pltpu.VMEM((c_true, dh), jnp.float32),    # gathered V
        ]
        if quantized:
            scratch.append(
                pltpu.VMEM((8, c_true), jnp.float32))  # v_scales
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rows, dh),
                               lambda bi, ki, pi, nv, bt: (bi, ki, 0, 0)),
        scratch_shapes=scratch,
    )
    nv = jnp.broadcast_to(n_valid.astype(jnp.int32).reshape(-1), (b,))
    bt = block_table.astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, n_p=n_p, t=t,
                          sm_scale=sm_scale, quantized=quantized,
                          op_dtype=mm_operand_dtype(), q_len=q_len,
                          gp=gp, online=online),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rows, dh), jnp.float32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(nv, bt, *args)
