"""Pallas TPU kernel: two-level microscaled FP8 GEMM (paper Fig 3b,
TPU-native — DESIGN.md §2).

y[m, n] = Σ_k ( Qx[m, k] · 2^sexp[m, k/32] ) · Qw[k, n]

The grid is (M/bm, N/bn, K/bk), K innermost ("arbitrary"); the f32
accumulator lives in VMEM scratch.  Per K-block the E8M0 subscale is an
exponent-only multiply applied to the *operand tile* on the VPU —
O(bm·bk) cheap work — and the MXU dot runs on the rescaled bf16 tile.
The single f32 epilogue multiply (s_x·s_w) happens OUTSIDE the kernel in
ops.py (the paper's "dequant in the epilogue on CUDA cores").

Contrast with group_gemm.py (COAT baseline): there an O(bm·bn) f32
multiply-accumulate of the partial-sum tile runs per K-block inside the
loop — the overhead MOSS eliminates.

Block shapes default to (128, 128, 512): MXU-aligned (multiples of 128)
and a VMEM working set of
  bm·bk (fp8) + bk·bn (fp8) + bm·bn·4 (f32 acc) + bm·bk/32 (int8)
= 64K + 64K + 64K·4 + 2K ≈ 0.4 MiB ≪ 16 MiB VMEM, leaving room for
double buffering of the HBM→VMEM pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.jaxapi import pallas_tpu_compiler_params

MICRO = 32


def _mx_gemm_kernel(qx_ref, se_ref, qw_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = qx_ref[...].astype(jnp.bfloat16)                  # (bm, bk)
    bm, bk = x.shape
    # E8M0 level-2 subscale: exponent-only operand rescale (exact in bf16)
    ss = jnp.exp2(se_ref[...].astype(jnp.float32)).astype(jnp.bfloat16)
    x = (x.reshape(bm, bk // MICRO, MICRO) * ss[:, :, None]
         ).reshape(bm, bk)
    w = qw_ref[...].astype(jnp.bfloat16)                  # (bk, bn)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def mx_gemm_pallas(qx, sexp, qw, *, bm: int = 128, bn: int = 128,
                   bk: int = 512, interpret: bool = False):
    """qx: (M, K) float8_e4m3fn; sexp: (M, K//32) int8; qw: (K, N) fp8.
    Returns the UNSCALED f32 accumulation (caller applies s_x·s_w)."""
    m, k = qx.shape
    n = qw.shape[1]
    assert k % MICRO == 0 and sexp.shape == (m, k // MICRO)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"(M,N,K)=({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    assert bk % MICRO == 0
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_mx_gemm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk // MICRO), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qx, sexp, qw)
