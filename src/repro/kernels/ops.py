"""Public convenience wrappers over the unified kernel dispatch.

Kept for benchmarks/examples and backward compatibility; the training
path (``repro.core.linear``) calls ``repro.kernels.dispatch`` directly.
Backend selection (pallas / interpret / ref) happens per call inside
dispatch, so flipping ``REPRO_KERNELS`` between calls takes effect
immediately — the Pallas kernels themselves are jitted with the
interpret flag static, which keeps jit caches per-backend.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import MxQ, PerGroupQ, PerTensorQ
from . import dispatch


def mx_quantize(x, fmt: str = "e4m3"):
    """Two-level microscaling quantize: returns (q, sexp, s_global)."""
    q = dispatch.mx_quantize(x, fmt=fmt)
    return q.q, q.sexp, q.s


def mx_matmul(qx, sexp, qw, s_x, s_w, out_dtype=jnp.bfloat16):
    """Full MOSS GEMM: kernel main loop + f32 epilogue (s_x·s_w)."""
    return dispatch.mx_matmul(MxQ(q=qx, sexp=sexp, s=s_x),
                              PerTensorQ(q=qw, s=s_w),
                              out_dtype=out_dtype)


def coat_matmul(qx, sx, qw, s_w, out_dtype=jnp.bfloat16):
    """COAT-baseline per-group GEMM (in-loop dequant) + weight epilogue."""
    return dispatch.group_matmul(PerGroupQ(q=qx, s=sx),
                                 PerTensorQ(q=qw, s=s_w),
                                 out_dtype=out_dtype)


def moss_linear(x, w, out_dtype=jnp.bfloat16):
    """End-to-end MOSS linear via the kernel path: fused two-level
    quantize + GEMM on the activation, per-tensor weight, f32 epilogue.
    K is zero-padded to a micro-group multiple (exact — zero groups
    quantize to zero and contribute nothing)."""
    from repro.core.quant import quant_per_tensor

    k = x.shape[-1]
    pad = (-k) % dispatch.MICRO
    x2d = x.reshape(-1, k)
    if pad:
        x2d = jnp.pad(x2d, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    wq = quant_per_tensor(w)
    y, _ = dispatch.fused_quant_matmul(x2d, wq, out_dtype=out_dtype)
    return y.reshape(*x.shape[:-1], w.shape[-1])
