"""Jit'd public wrappers with backend dispatch.

On TPU the Pallas kernels run natively; on CPU (this container) the
pure-jnp reference path executes (same semantics — the kernels are
validated against it in interpret mode by tests/test_kernels.py).
Set ``REPRO_KERNELS=interpret`` to force interpret-mode Pallas on CPU
(slow; used by the benchmark harness for kernel-path timing).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.formats import fp8_max
from . import ref
from .group_gemm import group_gemm_pallas
from .mx_gemm import mx_gemm_pallas
from .mx_quant import mx_quant_pallas


def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("fmt",))
def mx_quantize(x, fmt: str = "e4m3"):
    """Two-level microscaling quantize: returns (q, sexp, s_global)."""
    s = ref.global_scale_ref(x, fmt)
    mode = _mode()
    if mode == "pallas":
        q, e = mx_quant_pallas(x, s, fmt=fmt)
    elif mode == "interpret":
        q, e = mx_quant_pallas(x, s, fmt=fmt, interpret=True)
    else:
        q, e = ref.mx_quant_ref(x, s, fmt)
    return q, e, s


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def mx_matmul(qx, sexp, qw, s_x, s_w, out_dtype=jnp.bfloat16):
    """Full MOSS GEMM: kernel main loop + f32 epilogue (s_x·s_w)."""
    mode = _mode()
    if mode == "pallas":
        acc = mx_gemm_pallas(qx, sexp, qw)
    elif mode == "interpret":
        acc = mx_gemm_pallas(qx, sexp, qw, interpret=True)
    else:
        acc = ref.mx_gemm_ref(qx, sexp, qw)
    return (acc * (s_x * s_w)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def coat_matmul(qx, sx, qw, s_w, out_dtype=jnp.bfloat16):
    """COAT-baseline per-group GEMM (in-loop dequant) + weight epilogue."""
    mode = _mode()
    if mode == "pallas":
        acc = group_gemm_pallas(qx, sx, qw)
    elif mode == "interpret":
        acc = group_gemm_pallas(qx, sx, qw, interpret=True)
    else:
        acc = ref.group_gemm_ref(qx, sx, qw)
    return (acc * s_w).astype(out_dtype)


def moss_linear(x, w, out_dtype=jnp.bfloat16):
    """End-to-end MOSS linear via the kernel path: quantize activation
    (two-level), weight (per-tensor), GEMM, epilogue."""
    from repro.core.quant import quant_per_tensor

    qx, sexp, s_x = mx_quantize(x.reshape(-1, x.shape[-1]))
    wq = quant_per_tensor(w)
    y = mx_matmul(qx, sexp, wq.q, s_x, wq.s, out_dtype)
    return y.reshape(*x.shape[:-1], w.shape[-1])
