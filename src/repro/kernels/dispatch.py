"""Unified kernel dispatch — the single entry point for every quantized
GEMM and fused quantizer in the training path.

``repro.core.linear``'s custom-VJP (forward, dx and dW GEMMs) and the
public ``ops`` wrappers all route through this module; nothing above
this layer touches a Pallas kernel or the jnp reference directly.  Per
call the backend is chosen by ``repro.core.runtime_flags.kernel_backend``:

  pallas      Pallas-native TPU kernels (mx_fused / mx_gemm / mx_bwd /
              group_gemm / mx_quant)
  interpret   the same kernels under the Pallas interpreter — CPU
              parity testing of the *kernel* path (REPRO_KERNELS=interpret)
  ref         the pure-jnp semantic reference in repro.core.quant —
              the CPU execution default (XLA fuses it)

The kernel paths impose TPU-friendly alignment (M/N blocks of 128, K
micro-group multiples); this module zero-pads operands up to block
multiples and slices results back, so callers see one shape contract
across backends.  Zero padding is exact under every quantizer here
(amax of an all-zero group clamps to TINY → q = 0 → contributes 0).

Kernels hardcode the paper's micro-group of 32 and COAT group of 128;
non-default geometries silently take the reference path (they exist
only for ablations).

Weight operands always arrive here as fp8 payload + f32 scale
(``PerTensorQ``) — whether quantized in-graph by ``core.linear``
(training) or once at server build time (``PrequantParams``,
docs/serving.md) is invisible at this layer.  The full shape/padding
contract is written down in docs/kernel-contract.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core.quant import MxQ, PerGroupQ, PerTensorQ
from repro.core.runtime_flags import KERNEL_BACKENDS, kernel_backend
from . import ref
from .decode_attn import decode_attn_paged_pallas, decode_attn_pallas
from .group_gemm import GROUP, group_gemm_pallas
from .moe_gmm import moe_dw_gemm_pallas, moe_gmm_pallas
from .mx_bwd import mx_dw_gemm_pallas
from .mx_fused import fused_quant_gemm_pallas
from .mx_gemm import mx_gemm_pallas
from .mx_quant import mx_quant_pallas

MICRO = 32


def _resolve(backend: str | None) -> str:
    if backend is None:
        return kernel_backend()
    if backend not in KERNEL_BACKENDS:
        raise ValueError(
            f"backend={backend!r}: expected one of {KERNEL_BACKENDS}")
    return backend


def _ceil_to(v: int, mult: int) -> int:
    return v + (-v) % mult


def _pad_to(x: jax.Array, axis: int, target: int) -> jax.Array:
    if x.shape[axis] == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, widths)


def _k_block(kp: int) -> int:
    for b in (512, 256, 128, 64, 32):
        if kp % b == 0:
            return b
    raise AssertionError(f"K={kp} not a multiple of {MICRO}")


def _m_block(mp: int, min_mult: int = 8) -> int:
    for b in (256, 128, 64, 32, 16, 8):
        if b >= min_mult and mp % b == 0:
            return b
    raise AssertionError(f"M={mp} not a multiple of {min_mult}")


# ---------------------------------------------------------------------------
# MOSS (two-level microscaling) path
# ---------------------------------------------------------------------------


def mx_quantize(x2d: jax.Array, fmt: str = "e4m3",
                micro_group: int = MICRO,
                backend: str | None = None) -> MxQ:
    """Two-level microscaling quantize of a (M, K) tensor (K % micro)."""
    backend = _resolve(backend)
    assert x2d.shape[-1] % micro_group == 0, \
        f"K={x2d.shape[-1]} not divisible by micro_group={micro_group}"
    if backend == "ref" or micro_group != MICRO:
        return Q.quant_mx(x2d, micro_group, fmt)
    m, k = x2d.shape
    s = ref.global_scale_ref(x2d, fmt)
    mp = _ceil_to(m, 8)
    q, e = mx_quant_pallas(_pad_to(x2d, 0, mp), s, fmt=fmt,
                           bm=_m_block(mp), bk=_k_block(k),
                           interpret=backend == "interpret")
    return MxQ(q=q[:m], sexp=e[:m], s=s)


def mx_matmul(xq: MxQ, wq: PerTensorQ, out_dtype=jnp.bfloat16,
              backend: str | None = None) -> jax.Array:
    """MOSS GEMM (paper Fig. 3b): (Qx·2^sexp) @ Qw · s_x·s_w — the
    level-2 rescale rides the operand, one f32 epilogue multiply."""
    backend = _resolve(backend)
    micro = xq.q.shape[-1] // xq.sexp.shape[-1]
    if backend == "ref" or micro != MICRO or xq.q.ndim != 2:
        return Q.mx_gemm(xq, wq, out_dtype=out_dtype)
    m, k = xq.q.shape
    n = wq.q.shape[-1]
    mp, np_, kp = _ceil_to(m, 128), _ceil_to(n, 128), _ceil_to(k, MICRO)
    acc = mx_gemm_pallas(
        _pad_to(_pad_to(xq.q, 0, mp), 1, kp),
        _pad_to(_pad_to(xq.sexp, 0, mp), 1, kp // MICRO),
        _pad_to(_pad_to(wq.q, 0, kp), 1, np_),
        bm=128, bn=128, bk=_k_block(kp),
        interpret=backend == "interpret")
    return (acc[:m, :n] * (xq.s * wq.s)).astype(out_dtype)


def fused_quant_matmul(x2d: jax.Array, wq: PerTensorQ,
                       fmt: str = "e4m3", micro_group: int = MICRO,
                       out_dtype=jnp.bfloat16,
                       backend: str | None = None
                       ) -> tuple[jax.Array, MxQ]:
    """Fused quantize + MOSS GEMM: x (M, K) bf16/f32 in, finished GEMM
    plus the FP8 residual (for the custom-VJP) out — one pass over x,
    matching the paper's Fig. 3b steady-state HLO.  Serves the forward
    (x @ W) and the dx backward (g @ Wᵀ, E5M2)."""
    backend = _resolve(backend)
    # uniform shape contract across backends: the residual's micro-group
    # boundaries must tile K exactly (callers pad — see linear._pad_axis)
    assert x2d.shape[-1] % micro_group == 0, \
        f"K={x2d.shape[-1]} not divisible by micro_group={micro_group}"
    if backend == "ref" or micro_group != MICRO:
        xq = Q.quant_mx(x2d, micro_group, fmt)
        return Q.mx_gemm(xq, wq, out_dtype=out_dtype), xq
    m, k = x2d.shape
    n = wq.q.shape[-1]
    s = ref.global_scale_ref(x2d, fmt)
    mp, np_, kp = _ceil_to(m, 128), _ceil_to(n, 128), _ceil_to(k, MICRO)
    acc, q, sexp = fused_quant_gemm_pallas(
        _pad_to(_pad_to(x2d, 0, mp), 1, kp), s,
        _pad_to(_pad_to(wq.q, 0, kp), 1, np_),
        fmt=fmt, bm=128, bn=128, bk=_k_block(kp),
        interpret=backend == "interpret")
    y = (acc[:m, :n] * (s * wq.s)).astype(out_dtype)
    return y, MxQ(q=q[:m, :k], sexp=sexp[:m, :k // MICRO], s=s)


def mx_matmul_dw(xq: MxQ, gq: PerTensorQ, fmt: str = "e4m3",
                 out_dtype=jnp.float32, out_rows: int | None = None,
                 backend: str | None = None) -> jax.Array:
    """The dW backward GEMM: requant_M(x̂)ᵀ @ Qg · s_x·s_g, where x̂ is
    the FP8 forward residual and the re-quantization (micro-groups along
    the token dim, level-1 scale pinned to s_x so it cancels — see
    kernels/mx_bwd.py) is fused into the kernel.

    ``out_rows`` is the caller's true (unpadded) K: the residual's K dim
    carries the micro-group padding, so both branches slice the result
    to ``[:out_rows, :n]`` here — one shape contract, no caller-side
    defensive slicing."""
    backend = _resolve(backend)
    micro = xq.q.shape[-1] // xq.sexp.shape[-1]
    m, k = xq.q.shape
    n = gq.q.shape[-1]
    if backend == "ref" or micro != MICRO:
        mp = _ceil_to(m, micro)
        x_unit = MxQ(_pad_to(xq.q, 0, mp), _pad_to(xq.sexp, 0, mp),
                     jnp.float32(1.0)).dequant(jnp.float32)  # Qx·2^sexp
        xt = Q.quant_mx(x_unit.T, micro, fmt,
                        global_scale=jnp.float32(1.0))
        acc = Q.mx_gemm(xt, PerTensorQ(q=_pad_to(gq.q, 0, mp),
                                       s=jnp.float32(1.0)),
                        out_dtype=jnp.float32)
    else:
        mp, np_, kp = _ceil_to(m, 128), _ceil_to(n, 128), _ceil_to(k, MICRO)
        acc = mx_dw_gemm_pallas(
            _pad_to(_pad_to(xq.q, 0, mp), 1, kp),
            _pad_to(_pad_to(xq.sexp, 0, mp), 1, kp // MICRO),
            _pad_to(_pad_to(gq.q, 0, mp), 1, np_),
            fmt=fmt, bm=128, bn=128, bko=_k_block(kp),
            interpret=backend == "interpret")
    acc = acc[:k if out_rows is None else out_rows, :n]
    return (acc * (xq.s * gq.s)).astype(out_dtype)


# ---------------------------------------------------------------------------
# MOSS grouped-expert (MoE) path — one ragged kernel for every expert
# ---------------------------------------------------------------------------


def moe_grouped_matmul(x2d: jax.Array, group_sizes: jax.Array,
                       qw_stack: jax.Array, w_scales: jax.Array, *,
                       capacity: int, fmt: str = "e4m3",
                       micro_group: int = MICRO, out_dtype=jnp.bfloat16,
                       backend: str | None = None
                       ) -> tuple[jax.Array, MxQ]:
    """Fused two-level quantize + grouped-expert GEMM.

    ``x2d`` is the flat sorted token buffer ``(E·C, K)`` — expert ``e``
    owns rows ``[e·C, e·C + group_sizes[e])``, the rest of each capacity
    slot must be zero.  One global amax reduction covers the whole
    buffer (vs E per-expert reductions on the vmapped path); per-expert
    weight scales ``w_scales (E,)`` are applied row-wise in the
    epilogue.  Returns the finished GEMM ``(E·C, N)`` plus the fp8
    residual of the whole buffer (for the grouped custom-VJP)."""
    backend = _resolve(backend)
    t, k = x2d.shape
    e, kw, n = qw_stack.shape
    assert kw == k and t == e * capacity, (x2d.shape, qw_stack.shape)
    assert k % micro_group == 0, \
        f"K={k} not divisible by micro_group={micro_group}"
    s = ref.global_scale_ref(x2d, fmt)
    if backend == "ref" or micro_group != MICRO:
        xq = Q.quant_mx(x2d, micro_group, fmt, global_scale=s)
        acc = ref.moe_gmm_ref(xq.q, xq.sexp, qw_stack, capacity)
    else:
        np_ = _ceil_to(n, 128)
        acc, q, sexp = moe_gmm_pallas(
            x2d, s, _pad_to(qw_stack, 2, np_),
            group_sizes.astype(jnp.int32), capacity=capacity, fmt=fmt,
            bm=_m_block(capacity), bn=128, bk=_k_block(k),
            interpret=backend == "interpret")
        acc = acc[:, :n]
        xq = MxQ(q=q, sexp=sexp, s=s)
    row_scale = s * jnp.repeat(w_scales.astype(jnp.float32), capacity)
    y = (acc * row_scale[:, None]).astype(out_dtype)
    return y, xq


def moe_grouped_matmul_dw(xq: MxQ, gq: PerTensorQ,
                          group_sizes: jax.Array, *, capacity: int,
                          fmt: str = "e4m3", out_dtype=jnp.float32,
                          out_rows: int | None = None,
                          backend: str | None = None) -> jax.Array:
    """The grouped dW backward: per expert, requant_M(x̂_e)ᵀ @ Qg_e over
    that expert's row range — all experts in one launch, gradient
    quantized with ONE per-tensor scale.  Returns ``(E, K, N)`` (K
    sliced to ``out_rows`` when the residual carries micro padding).
    Per-expert rows are padded here to a micro-group multiple so the
    along-token requantization never straddles an expert boundary."""
    backend = _resolve(backend)
    t, k = xq.q.shape
    assert t % capacity == 0
    e = t // capacity
    n = gq.q.shape[-1]
    micro = xq.q.shape[-1] // xq.sexp.shape[-1]
    use_ref = backend == "ref" or micro != MICRO
    # per-expert rows padded so the along-token requant groups (micro
    # tokens each) never straddle an expert boundary
    cp = _ceil_to(capacity, micro if use_ref else MICRO)

    def _pad_rows(a):
        if cp == capacity:
            return a
        return _pad_to(a.reshape(e, capacity, *a.shape[1:]), 1,
                       cp).reshape(e * cp, *a.shape[1:])

    qx, sexp, qg = _pad_rows(xq.q), _pad_rows(xq.sexp), _pad_rows(gq.q)
    if use_ref:
        acc = ref.moe_dw_ref(qx, sexp, qg, cp, fmt, micro)
    else:
        np_ = _ceil_to(n, 128)
        acc = moe_dw_gemm_pallas(
            qx, sexp, _pad_to(qg, 1, np_),
            group_sizes.astype(jnp.int32), capacity=cp, fmt=fmt,
            bm=_m_block(cp, min_mult=MICRO), bn=128,
            bko=_k_block(k), interpret=backend == "interpret")
    acc = acc[:, :k if out_rows is None else out_rows, :n]
    return (acc * (xq.s * gq.s)).astype(out_dtype)


# ---------------------------------------------------------------------------
# Serving: fused decode attention over the (fp8 | bf16) KV cache
# ---------------------------------------------------------------------------


def decode_attention(q, k, v, k_scale, v_scale, n_valid, *,
                     sm_scale: float | None = None,
                     backend: str | None = None) -> jax.Array:
    """Single-step decode attention against the kv-head-major cache.

    ``q`` is (B, KV, G, Dh) — queries grouped by kv head (GQA); ``k`` /
    ``v`` are the cache payloads (B, KV, C, Dh) in e4m3 (with
    per-(token, kv-head) f32 ``k_scale``/``v_scale`` (B, KV, C)) or
    bf16 (scales None); ``n_valid`` is the cache ``idx`` — a scalar
    shared by every row (legacy ring) or a (B,) per-slot length
    vector (continuous-batching engine, docs/continuous-batching.md:
    slots at different depths coexist in one decode batch); every
    entry must be ≥ 1.  A scalar is broadcast to (B,) here, so both
    backends see one contract.
    Returns (B, KV, G, Dh) f32 — the caller reshapes heads and casts.

    The kernel path fuses scale application, ring-validity masking,
    softmax and the value combine into one launch reading the payload
    at 1 byte/element; the ref path is the scale-folding einsum oracle
    (``kernels/ref.py``), bitwise-identical on a bf16 cache with one C
    block (docs/decode-attention.md).  G is padded to the 8-row
    sublane tile here and sliced back; C and Dh pass through unpadded
    (the kernel masks the trailing partial block) so the cache is
    never copied.

    Batched-query (speculative verify) form: a 5-D ``q``
    (B, KV, S, G, Dh) carries S draft queries per row; ``n_valid`` is
    the POST-write depth (every entry ≥ S) and draft j's validity is
    ``slot < min(n_valid[b] - (S-1-j), C)`` — the in-step causal mask
    (docs/speculative-decoding.md).  Returns (B, KV, S, G, Dh) f32.
    The kernel path flattens the drafts into S·Gp draft-major rows
    sharing ONE cache read."""
    backend = _resolve(backend)
    s_len = q.shape[2] if q.ndim == 5 else 1
    b, kvh, g, dh = q.shape[0], q.shape[1], q.shape[-2], q.shape[-1]
    if sm_scale is None:
        sm_scale = dh ** -0.5
    nv = jnp.asarray(n_valid, jnp.int32).reshape(-1)
    assert nv.shape[0] in (1, b), \
        f"n_valid shape {nv.shape}: expected () / (1,) / ({b},)"
    nv = jnp.broadcast_to(nv, (b,))
    if backend == "ref":
        return ref.decode_attn_ref(q, k, v, k_scale, v_scale, nv,
                                   sm_scale=sm_scale)
    gp = _ceil_to(max(g, 8), 8)
    if q.ndim == 5:
        qf = _pad_to(q, 3, gp).reshape(b, kvh, s_len * gp, dh)
        out = decode_attn_pallas(
            qf, k, v, k_scale, v_scale, nv, sm_scale=sm_scale,
            interpret=backend == "interpret", q_len=s_len)
        return out.reshape(b, kvh, s_len, gp, dh)[:, :, :, :g]
    out = decode_attn_pallas(
        _pad_to(q, 2, gp), k, v, k_scale, v_scale, nv,
        sm_scale=sm_scale, interpret=backend == "interpret")
    return out[:, :, :g]


def decode_attention_paged(q, k, v, k_scale, v_scale, n_valid,
                           block_table, *,
                           sm_scale: float | None = None,
                           backend: str | None = None) -> jax.Array:
    """Single-step decode attention over the floating page pool.

    Same contract as :func:`decode_attention` except the cache arrives
    as a GLOBAL page pool — ``k`` / ``v`` are (P, KV, T, Dh) physical
    pages (e4m3 with (P, KV, T) f32 scales, or bf16 with scales None)
    shared by every slot, and ``block_table`` (B, NP) int32 maps
    logical page j of batch row b to physical row
    ``block_table[b, j]``.  ``n_valid`` must be per-slot (B,) (the
    engine's length vector); a scalar broadcasts as before.  Logical
    capacity is C = NP·T; validity is ``slot < min(n_valid[b], C)``.
    Returns (B, KV, G, Dh) f32.

    The ref path gathers the pages into the contiguous layout and
    reuses the contiguous oracle (bitwise-equal by construction); the
    kernel path threads ``block_table`` in as a second scalar-prefetch
    operand so its index maps perform the same gather inside the DMA
    schedule — nothing cache-sized is materialized in HBM
    (docs/paged-attention.md).  A 5-D ``q`` (B, KV, S, G, Dh) is the
    batched-query verify form, exactly as in
    :func:`decode_attention`."""
    backend = _resolve(backend)
    s_len = q.shape[2] if q.ndim == 5 else 1
    b, kvh, g, dh = q.shape[0], q.shape[1], q.shape[-2], q.shape[-1]
    if sm_scale is None:
        sm_scale = dh ** -0.5
    nv = jnp.asarray(n_valid, jnp.int32).reshape(-1)
    assert nv.shape[0] in (1, b), \
        f"n_valid shape {nv.shape}: expected () / (1,) / ({b},)"
    nv = jnp.broadcast_to(nv, (b,))
    bt = jnp.asarray(block_table, jnp.int32)
    assert bt.shape[0] == b, (bt.shape, b)
    if backend == "ref":
        return ref.decode_attn_paged_ref(q, k, v, k_scale, v_scale, nv,
                                         bt, sm_scale=sm_scale)
    gp = _ceil_to(max(g, 8), 8)
    if q.ndim == 5:
        qf = _pad_to(q, 3, gp).reshape(b, kvh, s_len * gp, dh)
        out = decode_attn_paged_pallas(
            qf, k, v, k_scale, v_scale, nv, bt, sm_scale=sm_scale,
            interpret=backend == "interpret", q_len=s_len)
        return out.reshape(b, kvh, s_len, gp, dh)[:, :, :, :g]
    out = decode_attn_paged_pallas(
        _pad_to(q, 2, gp), k, v, k_scale, v_scale, nv, bt,
        sm_scale=sm_scale, interpret=backend == "interpret")
    return out[:, :, :g]


# ---------------------------------------------------------------------------
# COAT (per-group) and TE (per-tensor) baselines
# ---------------------------------------------------------------------------


def group_matmul(xq: PerGroupQ, wq: PerTensorQ, out_dtype=jnp.bfloat16,
                 backend: str | None = None) -> jax.Array:
    """COAT-style GEMM (paper Fig. 3a): per-group f32 rescale of every
    partial sum inside the K loop — the overhead MOSS removes."""
    backend = _resolve(backend)
    group = xq.q.shape[-1] // xq.s.shape[-1]
    if backend == "ref" or group != GROUP or xq.q.ndim != 2:
        return Q.group_gemm(xq, wq, out_dtype=out_dtype)
    m, k = xq.q.shape
    n = wq.q.shape[-1]
    mp, np_ = _ceil_to(m, 128), _ceil_to(n, 128)
    acc = group_gemm_pallas(
        _pad_to(xq.q, 0, mp),
        _pad_to(xq.s, 0, mp),
        _pad_to(wq.q, 1, np_),
        bm=128, bn=128, bk=GROUP,
        interpret=backend == "interpret")
    return (acc[:m, :n] * wq.s).astype(out_dtype)


def pt_matmul(xq: PerTensorQ, wq: PerTensorQ, out_dtype=jnp.bfloat16,
              backend: str | None = None) -> jax.Array:
    """TE-style per-tensor GEMM.  Epilogue-only dequant: this is a plain
    FP8 matmul XLA already maps to the MXU, so every backend takes the
    reference path (there is nothing for a hand-written kernel to fuse)."""
    del backend
    return Q.pt_gemm(xq, wq, out_dtype=out_dtype)
