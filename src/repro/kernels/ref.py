"""Pure-jnp oracles for every Pallas kernel (the semantic ground truth —
``repro.core.quant`` is the single source of those semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import TINY, fp8_max
from repro.core.quant import MxQ, PerTensorQ, PerGroupQ
from repro.core import quant as Q


def mx_gemm_ref(qx, sexp, qw) -> jax.Array:
    """Unscaled MX GEMM accumulation: (Qx·2^sexp) @ Qw in f32."""
    y = Q.mx_gemm(MxQ(q=qx, sexp=sexp, s=jnp.float32(1.0)),
                  PerTensorQ(q=qw, s=jnp.float32(1.0)),
                  out_dtype=jnp.float32)
    return y


def group_gemm_ref(qx, sx, qw) -> jax.Array:
    """Per-group GEMM with activation group scales applied, weight scale
    NOT applied (matches group_gemm_pallas)."""
    return Q.group_gemm(PerGroupQ(q=qx, s=sx),
                        PerTensorQ(q=qw, s=jnp.float32(1.0)),
                        out_dtype=jnp.float32)


def moe_gmm_ref(qx, sexp, qw_stack, capacity: int) -> jax.Array:
    """Unscaled grouped-expert MX GEMM accumulation: row block
    ``[e·C, (e+1)·C)`` of ``(Qx·2^sexp)`` against ``qw_stack[e]``, all
    experts at once.  Rows beyond a group's valid count are zero by the
    dispatch precondition, so dense per-slot compute is exact."""
    from repro.core.formats import e8m0_decode
    from repro.core.runtime_flags import einsum

    t, k = qx.shape
    e = qw_stack.shape[0]
    g = k // sexp.shape[-1]
    ss = e8m0_decode(sexp).astype(jnp.bfloat16)
    xf = qx.astype(jnp.bfloat16).reshape(t, k // g, g)
    xf = (xf * ss[..., None]).reshape(e, capacity, k)
    return einsum("ecd,edf->ecf", xf, qw_stack,
                  out_dtype=jnp.float32).reshape(t, -1)


def moe_dw_ref(qx, sexp, qg, capacity: int, fmt: str = "e4m3",
               micro: int = 32) -> jax.Array:
    """Unscaled grouped dW accumulation (E, K, N): per expert slice,
    dequant the fp8 residual by its level-2 exponents, requantize along
    the token dim (micro-groups of ``micro`` tokens, level-1 scale
    pinned to 1 — s_x cancels, see kernels/mx_bwd.py), and contract
    over that expert's rows."""
    t, k = qx.shape
    e = t // capacity
    n = qg.shape[-1]

    def one(qx_e, se_e, qg_e):
        x_unit = MxQ(qx_e, se_e, jnp.float32(1.0)).dequant(jnp.float32)
        xt = Q.quant_mx(x_unit.T, micro, fmt,
                        global_scale=jnp.float32(1.0))
        return Q.mx_gemm(xt, PerTensorQ(q=qg_e, s=jnp.float32(1.0)),
                         out_dtype=jnp.float32)

    return jax.vmap(one)(qx.reshape(e, capacity, k),
                         sexp.reshape(e, capacity, -1),
                         qg.reshape(e, capacity, n))


def decode_attn_ref(q, k, v, k_scale, v_scale, n_valid, *,
                    sm_scale: float) -> jax.Array:
    """Einsum decode attention over the kv-head-major cache — the
    semantic oracle for ``decode_attn_pallas`` AND the
    ``REPRO_DECODE_ATTN=einsum`` escape hatch (same function, one
    source of truth).

    q: (B, KV, G, Dh); k/v: (B, KV, C, Dh) e4m3|bf16 payloads;
    k_scale/v_scale: (B, KV, C) f32 or both None; n_valid: () int32
    shared across rows, or (B,) int32 per-slot valid counts (the
    continuous-batching engine's length vector — slots at different
    depths coexist in one decode batch).  Per-(token, kv-head) scales
    fold into the score (K) and the combine weight (V) instead of
    dequantizing the payload; slot validity per batch row b is
    ``slot < min(n_valid[b], C)`` (ring: a wrapped cache is fully
    valid).  Returns (B, KV, G, Dh) f32.

    Batched-query (speculative verify) form: a 5-D q
    (B, KV, S, G, Dh) carries S draft queries per row under the
    in-step causal mask — draft j attends
    ``slot < min(n_valid[b] - (S-1-j), C)``, with n_valid the
    POST-write depth (so draft j sees its own freshly-written K/V and
    every earlier draft's, but no later one's).  Every n_valid entry
    must be ≥ S.  Returns (B, KV, S, G, Dh) f32."""
    from repro.core.runtime_flags import einsum

    b, c = q.shape[0], k.shape[2]
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1),
                          (b,))
    if q.ndim == 5:
        s_len = q.shape[2]
        scores = einsum("bksgd,bktd->bksgt", q, k,
                        out_dtype=jnp.float32) * sm_scale
        if k_scale is not None:
            scores = scores * k_scale[:, :, None, None, :]
        lim = jnp.minimum(
            nv[:, None] - (s_len - 1 - jnp.arange(s_len))[None, :], c)
        valid = jnp.arange(c)[None, None, :] < lim[:, :, None]
        scores = jnp.where(valid[:, None, :, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        if v_scale is not None:
            w = w * v_scale[:, :, None, None, :]
        return einsum("bksgt,bktd->bksgd", w, v, out_dtype=jnp.float32)
    scores = einsum("bkgd,bktd->bkgt", q, k,
                    out_dtype=jnp.float32) * sm_scale
    if k_scale is not None:
        scores = scores * k_scale[:, :, None, :]
    valid = jnp.arange(c)[None, :] < jnp.minimum(nv, c)[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        w = w * v_scale[:, :, None, :]
    return einsum("bkgt,bktd->bkgd", w, v, out_dtype=jnp.float32)


def gather_pages(pool, block_table) -> jax.Array:
    """Gather a contiguous per-slot view out of the floating page pool.

    pool: (P, KV, T, ...) physical pages (payload (P, KV, T, Dh) or
    scale (P, KV, T)); block_table: (B, NP) int32 — logical page j of
    slot b lives in physical row ``block_table[b, j]``.  Returns
    (B, KV, NP·T, ...): the same layout the contiguous decode oracle
    consumes, so paged ref == contiguous ref by construction."""
    b, n_p = block_table.shape
    g = pool[block_table]                 # (B, NP, KV, T, ...)
    g = jnp.moveaxis(g, 2, 1)             # (B, KV, NP, T, ...)
    return g.reshape(b, g.shape[1], n_p * pool.shape[2],
                     *pool.shape[3:])


def decode_attn_paged_ref(q, k, v, k_scale, v_scale, n_valid,
                          block_table, *, sm_scale: float) -> jax.Array:
    """Floating-page decode oracle: gather each slot's pages into the
    contiguous (B, KV, C, ·) layout, then delegate to the contiguous
    oracle — paged-vs-contiguous parity is bitwise BY CONSTRUCTION on
    this backend.

    q: (B, KV, G, Dh); k/v: (P, KV, T, Dh) page-pool payloads;
    k_scale/v_scale: (P, KV, T) f32 or both None; n_valid: (B,) int32
    logical depths; block_table: (B, NP) int32.  Returns
    (B, KV, G, Dh) f32."""
    bt = jnp.asarray(block_table, jnp.int32)
    kg = gather_pages(k, bt)
    vg = gather_pages(v, bt)
    ksg = None if k_scale is None else gather_pages(k_scale, bt)
    vsg = None if v_scale is None else gather_pages(v_scale, bt)
    return decode_attn_ref(q, kg, vg, ksg, vsg, n_valid,
                           sm_scale=sm_scale)


def mx_quant_ref(x, s_global, fmt: str = "e4m3"):
    """Two-level quantize given a precomputed global scale."""
    q = Q.quant_mx(x, micro_group=32, fmt=fmt, global_scale=s_global)
    return q.q, q.sexp


def global_scale_ref(x, fmt: str = "e4m3", micro: int = 32):
    """Level-1 scale: max over the per-group fine scales (== amax/MAX)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.maximum(amax, TINY) / fp8_max(fmt)
