"""Pure-jnp oracles for every Pallas kernel (the semantic ground truth —
``repro.core.quant`` is the single source of those semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import TINY, fp8_max
from repro.core.quant import MxQ, PerTensorQ, PerGroupQ
from repro.core import quant as Q


def mx_gemm_ref(qx, sexp, qw) -> jax.Array:
    """Unscaled MX GEMM accumulation: (Qx·2^sexp) @ Qw in f32."""
    y = Q.mx_gemm(MxQ(q=qx, sexp=sexp, s=jnp.float32(1.0)),
                  PerTensorQ(q=qw, s=jnp.float32(1.0)),
                  out_dtype=jnp.float32)
    return y


def group_gemm_ref(qx, sx, qw) -> jax.Array:
    """Per-group GEMM with activation group scales applied, weight scale
    NOT applied (matches group_gemm_pallas)."""
    return Q.group_gemm(PerGroupQ(q=qx, s=sx),
                        PerTensorQ(q=qw, s=jnp.float32(1.0)),
                        out_dtype=jnp.float32)


def mx_quant_ref(x, s_global, fmt: str = "e4m3"):
    """Two-level quantize given a precomputed global scale."""
    q = Q.quant_mx(x, micro_group=32, fmt=fmt, global_scale=s_global)
    return q.q, q.sexp


def global_scale_ref(x, fmt: str = "e4m3", micro: int = 32):
    """Level-1 scale: max over the per-group fine scales (== amax/MAX)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.maximum(amax, TINY) / fp8_max(fmt)
