"""Pallas TPU kernel: fused two-level quantize + microscaled FP8 GEMM.

This is the steady-state operator of the MOSS training path (paper
Fig. 3b): the activation (forward) or gradient (backward-dx) enters in
bf16/f32 and leaves as a finished GEMM accumulation — the quantizer
never round-trips through HBM.  Per (bm, bk) LHS tile the kernel

  1. groups 32-wide micro-groups, takes amaxes,
  2. derives the E8M0 level-2 exponents against the (precomputed)
     level-1 global scale,
  3. performs the saturating FP8 cast,
  4. applies the exponent-only operand rescale (exact in bf16), and
  5. runs the MXU dot against the FP8 RHS tile,

emitting the f32 accumulation *and* the quantized payload (q, sexp) so
the custom-VJP can keep the FP8 residual for the backward pass without
a second quantization pass.  The single f32 epilogue multiply
(s_x · s_w) happens outside in the dispatch layer.

Grid (M/bm, N/bn, K/bk), K innermost ("arbitrary"); q/sexp blocks are
indexed (i, kk) only, so each is (re)written identically once per
N-block — dead writes the Mosaic pipeliner keeps in VMEM.

VMEM working set at the default (128, 128, 512) blocks:
  bm·bk·4 (x) + bk·bn (qw) + bm·bn·4 (acc) + bm·bk (q) + bm·bk/32 (se)
≈ 0.45 MiB ≪ 16 MiB, leaving headroom for double buffering.

Operand contract (see docs/kernel-contract.md)
----------------------------------------------
  x         (M, K) f32/bf16 — the unquantized LHS
  s_global  ()     f32      — precomputed level-1 scale (one amax over
                              x, done by the dispatch layer)
  qw        (K, N) fp8      — per-tensor-quantized RHS *payload*; its
                              f32 scale s_w stays with the caller
  returns   acc (M, N) f32 UNSCALED, q (M, K) fp8, sexp (M, K//32) int8

Two-level scale convention: the effective scale of LHS micro-group g is
``s_global · 2^sexp[g]`` with ``2^sexp ∈ (0, 1]``; the kernel applies
only the exponent part on the operand path (exact in bf16), so the
caller's single epilogue multiply is ``acc · s_global · s_w``.

Padding is CALLER-owned (repro.kernels.dispatch): M and N zero-padded
to block multiples, K to a micro-group multiple; this function only
*asserts* divisibility.  Zero padding is exact — a zero micro-group
quantizes to q = 0 at the E8M0 floor and contributes nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.jaxapi import pallas_tpu_compiler_params
from repro.core.formats import E4M3_MAX, E5M2_MAX

MICRO = 32
_TINY = 1e-30


def _fused_quant_gemm_kernel(x_ref, s_ref, qw_ref, o_ref, q_ref, se_ref,
                             acc_ref, *, n_k: int, fp8_max: float,
                             q_dtype):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                    # (bm, bk)
    bm, bk = x.shape
    s = jnp.maximum(s_ref[0, 0], _TINY)
    xg = x.reshape(bm, bk // MICRO, MICRO)
    amax = jnp.max(jnp.abs(xg), axis=-1)                  # (bm, bk/32)
    # E8M0 encode (identical guards to formats.e8m0_encode / mx_quant.py)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax / fp8_max / s,
                                      2.0 ** -149)) - 1e-6)
    e = jnp.clip(e, -127, 127)
    se_ref[...] = e.astype(jnp.int8)
    denom = jnp.exp2(e) * s
    safe = jnp.where(denom > 0, denom, 1.0)[..., None]
    q = jnp.where(denom[..., None] > 0, xg / safe, 0.0)
    q = jnp.clip(q, -fp8_max, fp8_max).astype(q_dtype)    # saturating cast
    q_ref[...] = q.reshape(bm, bk)
    # operand path: quantized values × 2^e (exponent-only; exact in bf16)
    ss = jnp.exp2(e).astype(jnp.bfloat16)
    xop = (q.astype(jnp.bfloat16) * ss[..., None]).reshape(bm, bk)
    w = qw_ref[...].astype(jnp.bfloat16)                  # (bk, bn)
    acc_ref[...] += jnp.dot(xop, w, preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("fmt", "bm", "bn", "bk", "interpret"))
def fused_quant_gemm_pallas(x, s_global, qw, *, fmt: str = "e4m3",
                            bm: int = 128, bn: int = 128, bk: int = 512,
                            interpret: bool = False):
    """x: (M, K) f32/bf16; s_global: () f32 level-1 scale; qw: (K, N)
    fp8 payload (e4m3/e5m2 per ``fmt``; the RHS f32 scale stays with
    the caller).  Returns (acc f32 (M, N) UNSCALED, q fp8 (M, K),
    sexp int8 (M, K//32)); the caller applies the s_x·s_w epilogue and
    owns the residual.  The caller also owns padding: M % bm == 0,
    N % bn == 0, K % bk == 0 and bk % 32 == 0 are asserted, never
    fixed up here (see the module docstring / docs/kernel-contract.md)."""
    m, k = x.shape
    n = qw.shape[1]
    assert k == qw.shape[0] and k % MICRO == 0
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"(M,N,K)=({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    assert bk % MICRO == 0
    fp8max = E4M3_MAX if fmt == "e4m3" else E5M2_MAX
    q_dtype = jnp.float8_e4m3fn if fmt == "e4m3" else jnp.float8_e5m2
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    acc, q, sexp = pl.pallas_call(
        functools.partial(_fused_quant_gemm_kernel, n_k=n_k,
                          fp8_max=fp8max, q_dtype=q_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk // MICRO), lambda i, j, kk: (i, kk)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, k), q_dtype),
            jax.ShapeDtypeStruct((m, k // MICRO), jnp.int8),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, s_global.reshape(1, 1), qw)
    return acc, q, sexp
