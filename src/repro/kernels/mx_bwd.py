"""Pallas TPU kernel: the dW backward GEMM of the MOSS custom-VJP.

  dW[k, n] = Σ_m requant_M(x̂)[k, m] · Qg[m, n]

where x̂ is the FP8 forward residual dequantized (x̂ = Qx · 2^sexp · s_x)
and ``requant_M`` re-quantizes the *transposed* activation with 32-wide
micro-groups along the token (M) dimension — the inner dimension of the
dW GEMM — so the level-2 scales again ride the operand and the single
f32 dequant stays in the epilogue (paper Fig. 3b applied to backward).

Key identity making the fusion cheap: re-quantizing against the SAME
level-1 scale s_x the forward used makes s_x cancel out of the in-kernel
arithmetic —

  x̂/s_x = Qx·2^sexp,    e' = ceil(log2(amax_M(x̂/s_x)/FP8_MAX)),
  q' = cast_fp8((x̂/s_x)/2^e'),

so the kernel needs only the fp8 residual + its exponents, never a f32
activation and never a second global amax reduction.  (This pins the dW
requant's level-1 scale to s_x; since every |x̂| ≤ FP8_MAX·s_x the ratio
is ≤ 1 and the E8M0 ceil guarantee still holds — same trade COAT makes
with its transposed quantized copy, minus the extra memory pass.)

Grid (K/bko, N/bn, M/bm), M (the contraction) innermost "arbitrary";
per M-block the kernel dequants Qx·2^sexp, transposes in-VMEM, requants
along M, rescales the operand by 2^e', and accumulates the MXU dot with
the E5M2 gradient tile.  Epilogue (× s_x·s_g) happens in the dispatch
layer.

Operand contract (see docs/kernel-contract.md)
----------------------------------------------
  qx      (M, K)      fp8  — the forward residual payload (E4M3)
  sexp    (M, K//32)  int8 — its level-2 E8M0 exponents
  qg      (M, N)      fp8  — per-tensor-quantized gradient payload
                             (E5M2 by default); s_g stays with caller
  returns (K, N) f32 UNSCALED dW accumulation

Two-level scale convention: both fp8 operands are in "units of their
level-1 scale" — qx·2^sexp ≡ x/s_x and qg ≡ g/s_g — so the caller's
epilogue is one multiply by s_x·s_g.  The in-kernel requant along M
re-uses s_x as its level-1 scale, which is why s_x never appears in
the kernel arithmetic.

Padding is CALLER-owned (repro.kernels.dispatch): M zero-padded to a
bm (and 32) multiple, N to bn, K to bko; the residual's K may carry
the forward's micro-group padding — the caller slices the result rows
back with ``out_rows`` in ``dispatch.mx_matmul_dw``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.jaxapi import pallas_tpu_compiler_params
from repro.core.formats import E4M3_MAX, E5M2_MAX

MICRO = 32


def _mx_dw_gemm_kernel(qx_ref, se_ref, qg_ref, o_ref, acc_ref, *,
                       n_m: int, fp8_max: float, q_dtype):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = qx_ref[...].astype(jnp.float32)                   # (bm, bko)
    bm, bko = x.shape
    # dequant by the forward's level-2 exponents (units of s_x)
    ss_fwd = jnp.exp2(se_ref[...].astype(jnp.float32))    # (bm, bko/32)
    xd = (x.reshape(bm, bko // MICRO, MICRO) * ss_fwd[..., None]
          ).reshape(bm, bko)
    xt = xd.T                                             # (bko, bm)
    # requant along M: micro-groups of 32 tokens, level-1 scale = s_x
    # (which cancels — see module docstring)
    xg = xt.reshape(bko, bm // MICRO, MICRO)
    amax = jnp.max(jnp.abs(xg), axis=-1)                  # (bko, bm/32)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax / fp8_max,
                                      2.0 ** -149)) - 1e-6)
    e = jnp.clip(e, -127, 127)
    ss = jnp.exp2(e)
    safe = jnp.where(ss > 0, ss, 1.0)[..., None]
    q = jnp.where(ss[..., None] > 0, xg / safe, 0.0)
    q = jnp.clip(q, -fp8_max, fp8_max).astype(q_dtype)    # fp8 requant
    # operand: requantized values × 2^e (exact po2 rescale in bf16)
    xop = (q.astype(jnp.bfloat16) * ss.astype(jnp.bfloat16)[..., None]
           ).reshape(bko, bm)
    g = qg_ref[...].astype(jnp.bfloat16)                  # (bm, bn)
    acc_ref[...] += jnp.dot(xop, g, preferred_element_type=jnp.float32)

    @pl.when(mi == n_m - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("fmt", "bm", "bn", "bko", "interpret"))
def mx_dw_gemm_pallas(qx, sexp, qg, *, fmt: str = "e4m3", bm: int = 128,
                      bn: int = 128, bko: int = 256,
                      interpret: bool = False):
    """qx: (M, K) fp8 forward residual; sexp: (M, K//32) int8; qg: (M, N)
    fp8 gradient (per-tensor scaled).  Returns the UNSCALED f32 dW
    accumulation (K, N); the caller applies s_x·s_g in the epilogue.
    Caller owns padding: M % 32 == 0 and block divisibility of (M, N,
    K) are asserted, never fixed up here."""
    m, k = qx.shape
    n = qg.shape[1]
    assert qg.shape[0] == m and sexp.shape == (m, k // MICRO)
    assert m % MICRO == 0, f"M={m} must be a multiple of {MICRO}"
    bm, bn, bko = min(bm, m), min(bn, n), min(bko, k)
    assert m % bm == 0 and n % bn == 0 and k % bko == 0, \
        f"(M,N,K)=({m},{n},{k}) not divisible by blocks ({bm},{bn},{bko})"
    assert bm % MICRO == 0 and bko % MICRO == 0
    fp8max = E4M3_MAX if fmt == "e4m3" else E5M2_MAX
    q_dtype = jnp.float8_e4m3fn if fmt == "e4m3" else jnp.float8_e5m2
    n_m = m // bm
    grid = (k // bko, n // bn, n_m)
    return pl.pallas_call(
        functools.partial(_mx_dw_gemm_kernel, n_m=n_m, fp8_max=fp8max,
                          q_dtype=q_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bko), lambda ki, ni, mi: (mi, ki)),
            pl.BlockSpec((bm, bko // MICRO), lambda ki, ni, mi: (mi, ki)),
            pl.BlockSpec((bm, bn), lambda ki, ni, mi: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((bko, bn), lambda ki, ni, mi: (ki, ni)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bko, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qx, sexp, qg)
