"""Pallas TPU kernels: MOSS-quantized grouped-expert GEMM (MoE hot path).

The MoE expert FFN used to run as ``jax.vmap`` over per-expert
``qlinear`` calls: E independent fused-quant GEMMs over the
capacity-padded ``(E, C, d)`` dispatch buffer, each with its own global
amax reduction — 3·E kernel launches + E reductions per MoE block.
These kernels collapse that to one launch per GEMM (up / gate / down)
and ONE level-1 amax over the whole token buffer:

``moe_gmm_pallas``
    Fused two-level quantize + grouped GEMM.  The flat sorted token
    buffer ``(E·C, K)`` — expert ``e`` owns rows ``[e·C, e·C+sizes[e])``,
    the remainder of each capacity slot is zero — is quantized exactly
    like ``mx_fused.py`` (one global scale, per-micro-group E8M0
    exponents, fp8 residual emitted for the backward) and every row
    block is multiplied against ITS expert's fp8 weight
    (``qw_stack[(i·bm)//C]``).  The ragged group sizes ride in as
    scalar-prefetch operands (SMEM): row blocks past a group's valid
    count skip the MXU dot entirely, so zero-size experts and
    capacity-padding rows cost no FLOPs.  Per-expert weight scales are
    applied row-wise in the dispatch-layer epilogue.

``moe_dw_gemm_pallas``
    The grouped dW backward: for every expert, ``requant_M(x̂_e)ᵀ @ Qg_e``
    over that expert's row range — the ``mx_bwd.py`` fusion
    (dequant → transpose → requant along tokens, level-1 scale pinned to
    s_x so it cancels in-kernel) with an extra expert grid dimension
    writing the stacked ``(E, K, N)`` weight gradient in one launch.

Both kernels require ``C % bm == 0`` so a row block never straddles an
expert boundary (the dispatch layer picks ``bm`` from the capacity and
pads per-expert rows to a micro-group multiple for dW).  Semantics are
defined over ALL ``E·C`` rows — group sizes are a compute-skipping hint
that is exact because rows beyond a group's size are zero (amax of a
zero micro-group clamps to the E8M0 floor → q = 0 → contributes 0).

Operand contract (see docs/kernel-contract.md)
----------------------------------------------
``moe_gmm_pallas``:
  x           (E·C, K)   f32/bf16 — flat sorted token buffer
  s_global    ()         f32      — ONE level-1 scale for the buffer
  qw_stack    (E, K, N)  fp8      — per-expert per-tensor payloads;
                                    the (E,) f32 scales stay with the
                                    caller (row-wise epilogue)
  group_sizes (E,)       int32    — scalar-prefetch (SMEM) operand
  returns acc (E·C, N) f32 UNSCALED, q (E·C, K) fp8,
          sexp (E·C, K//32) int8
``moe_dw_gemm_pallas``:
  qx (E·C, K) fp8 + sexp (E·C, K//32) int8 — grouped forward residual
  qg (E·C, N) fp8 — gradient, ONE per-tensor scale for the buffer
  returns (E, K, N) f32 UNSCALED stacked dW

Two-level scale convention matches mx_fused/mx_bwd: fp8 payloads are
in units of their level-1 scale; epilogues (s_x·s_w[e] row-wise for
forward, s_x·s_g for dW) live in the dispatch layer.

Padding is CALLER-owned (repro.kernels.dispatch): N zero-padded to a
bn multiple, K to a micro-group multiple, and — for dW — each expert's
capacity slot padded to a 32-row multiple so along-token micro-groups
never straddle experts.  These functions assert, never pad.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.jaxapi import pallas_tpu_compiler_params
from repro.core.formats import E4M3_MAX, E5M2_MAX

MICRO = 32
_TINY = 1e-30


# ---------------------------------------------------------------------------
# Forward / dx: fused two-level quantize + grouped GEMM
# ---------------------------------------------------------------------------


def _moe_gmm_kernel(sz_ref, x_ref, s_ref, qw_ref, o_ref, q_ref, se_ref,
                    acc_ref, *, n_k: int, cap: int, bm: int,
                    fp8_max: float, q_dtype):
    i = pl.program_id(0)
    kk = pl.program_id(2)
    e = (i * bm) // cap

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # quantize unconditionally: the residual must cover every row (zero
    # rows quantize to q=0 / sexp=-127, bit-identical to the reference)
    x = x_ref[...].astype(jnp.float32)                    # (bm, bk)
    bm_, bk = x.shape
    s = jnp.maximum(s_ref[0, 0], _TINY)
    xg = x.reshape(bm_, bk // MICRO, MICRO)
    amax = jnp.max(jnp.abs(xg), axis=-1)                  # (bm, bk/32)
    ee = jnp.ceil(jnp.log2(jnp.maximum(amax / fp8_max / s,
                                       2.0 ** -149)) - 1e-6)
    ee = jnp.clip(ee, -127, 127)
    se_ref[...] = ee.astype(jnp.int8)
    denom = jnp.exp2(ee) * s
    safe = jnp.where(denom > 0, denom, 1.0)[..., None]
    q = jnp.where(denom[..., None] > 0, xg / safe, 0.0)
    q = jnp.clip(q, -fp8_max, fp8_max).astype(q_dtype)    # saturating cast
    q_ref[...] = q.reshape(bm_, bk)

    # grouped MXU dot — skipped for row blocks past the group's count
    @pl.when((i * bm) % cap < sz_ref[e])
    def _dot():
        ss = jnp.exp2(ee).astype(jnp.bfloat16)
        xop = (q.astype(jnp.bfloat16) * ss[..., None]).reshape(bm_, bk)
        w = qw_ref[0].astype(jnp.bfloat16)                # (bk, bn)
        acc_ref[...] += jnp.dot(xop, w,
                                preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("capacity", "fmt", "bm", "bn", "bk",
                                    "interpret"))
def moe_gmm_pallas(x, s_global, qw_stack, group_sizes, *, capacity: int,
                   fmt: str = "e4m3", bm: int = 128, bn: int = 128,
                   bk: int = 512, interpret: bool = False):
    """x: (E·C, K) f32/bf16 grouped token buffer; s_global: () f32
    level-1 scale; qw_stack: (E, K, N) fp8; group_sizes: (E,) int32.
    Returns (acc f32 (E·C, N) UNSCALED, q fp8 (E·C, K), sexp int8
    (E·C, K//32)); the caller applies the s_x·s_w[e] row-wise epilogue
    and owns the residual.  Caller owns padding/alignment: C % bm == 0,
    N % bn == 0, K % bk == 0, bk % 32 == 0 are asserted, never fixed
    up here (docs/kernel-contract.md)."""
    t, k = x.shape
    e, kw, n = qw_stack.shape
    assert kw == k and k % MICRO == 0
    assert t == e * capacity, (t, e, capacity)
    assert group_sizes.shape == (e,)
    bm, bn, bk = min(bm, capacity), min(bn, n), min(bk, k)
    assert capacity % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"(C,N,K)=({capacity},{n},{k}) not divisible by ({bm},{bn},{bk})"
    assert bk % MICRO == 0
    fp8max = E4M3_MAX if fmt == "e4m3" else E5M2_MAX
    q_dtype = jnp.float8_e4m3fn if fmt == "e4m3" else jnp.float8_e5m2
    n_k = k // bk
    grid = (t // bm, n // bn, n_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, sz: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, j, kk, sz: (0, 0)),
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, kk, sz: ((i * bm) // capacity, kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk, sz: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk, sz: (i, kk)),
            pl.BlockSpec((bm, bk // MICRO), lambda i, j, kk, sz: (i, kk)),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    acc, q, sexp = pl.pallas_call(
        functools.partial(_moe_gmm_kernel, n_k=n_k, cap=capacity, bm=bm,
                          fp8_max=fp8max, q_dtype=q_dtype),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((t, n), jnp.float32),
            jax.ShapeDtypeStruct((t, k), q_dtype),
            jax.ShapeDtypeStruct((t, k // MICRO), jnp.int8),
        ],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(group_sizes, x, s_global.reshape(1, 1), qw_stack)
    return acc, q, sexp


# ---------------------------------------------------------------------------
# dW: grouped requant-along-tokens GEMM (one launch for all experts)
# ---------------------------------------------------------------------------


def _moe_dw_kernel(sz_ref, qx_ref, se_ref, qg_ref, o_ref, acc_ref, *,
                   n_m: int, bm: int, fp8_max: float, q_dtype):
    ei = pl.program_id(0)
    mi = pl.program_id(3)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mi * bm < sz_ref[ei])
    def _dot():
        x = qx_ref[...].astype(jnp.float32)               # (bm, bko)
        bm_, bko = x.shape
        # dequant by the forward's level-2 exponents (units of s_x)
        ss_fwd = jnp.exp2(se_ref[...].astype(jnp.float32))
        xd = (x.reshape(bm_, bko // MICRO, MICRO) * ss_fwd[..., None]
              ).reshape(bm_, bko)
        xt = xd.T                                         # (bko, bm)
        # requant along M (tokens of THIS expert's row range); level-1
        # scale pinned to s_x, which cancels — see kernels/mx_bwd.py
        xg = xt.reshape(bko, bm_ // MICRO, MICRO)
        amax = jnp.max(jnp.abs(xg), axis=-1)
        ee = jnp.ceil(jnp.log2(jnp.maximum(amax / fp8_max,
                                           2.0 ** -149)) - 1e-6)
        ee = jnp.clip(ee, -127, 127)
        ss = jnp.exp2(ee)
        safe = jnp.where(ss > 0, ss, 1.0)[..., None]
        q = jnp.where(ss[..., None] > 0, xg / safe, 0.0)
        q = jnp.clip(q, -fp8_max, fp8_max).astype(q_dtype)
        xop = (q.astype(jnp.bfloat16)
               * ss.astype(jnp.bfloat16)[..., None]).reshape(bko, bm_)
        g = qg_ref[...].astype(jnp.bfloat16)              # (bm, bn)
        acc_ref[...] += jnp.dot(xop, g,
                                preferred_element_type=jnp.float32)

    @pl.when(mi == n_m - 1)
    def _done():
        o_ref[0] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("capacity", "fmt", "bm", "bn", "bko",
                                    "interpret"))
def moe_dw_gemm_pallas(qx, sexp, qg, group_sizes, *, capacity: int,
                       fmt: str = "e4m3", bm: int = 128, bn: int = 128,
                       bko: int = 256, interpret: bool = False):
    """qx: (E·C, K) fp8 forward residual; sexp: (E·C, K//32) int8;
    qg: (E·C, N) fp8 (per-tensor scaled); group_sizes: (E,) int32.
    Returns the UNSCALED f32 stacked weight gradient (E, K, N); the
    caller applies s_x·s_g in the epilogue.  Requires C % 32 == 0 so
    the along-token micro-groups never straddle an expert boundary."""
    t, k = qx.shape
    n = qg.shape[1]
    assert qg.shape[0] == t and sexp.shape == (t, k // MICRO)
    assert t % capacity == 0
    e = t // capacity
    assert group_sizes.shape == (e,)
    assert capacity % MICRO == 0, \
        f"C={capacity} must be a multiple of {MICRO} (dispatch pads)"
    bm, bn, bko = min(bm, capacity), min(bn, n), min(bko, k)
    assert capacity % bm == 0 and n % bn == 0 and k % bko == 0, \
        f"(C,N,K)=({capacity},{n},{k}) not divisible by ({bm},{bn},{bko})"
    assert bm % MICRO == 0 and bko % MICRO == 0
    fp8max = E4M3_MAX if fmt == "e4m3" else E5M2_MAX
    q_dtype = jnp.float8_e4m3fn if fmt == "e4m3" else jnp.float8_e5m2
    n_m = capacity // bm          # row blocks per expert slot
    grid = (e, k // bko, n // bn, n_m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bko),
                         lambda ei, ki, ni, mi, sz: (ei * n_m + mi, ki)),
            pl.BlockSpec((bm, bko // MICRO),
                         lambda ei, ki, ni, mi, sz: (ei * n_m + mi, ki)),
            pl.BlockSpec((bm, bn),
                         lambda ei, ki, ni, mi, sz: (ei * n_m + mi, ni)),
        ],
        out_specs=pl.BlockSpec((1, bko, bn),
                               lambda ei, ki, ni, mi, sz: (ei, ki, ni)),
        scratch_shapes=[pltpu.VMEM((bko, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_moe_dw_kernel, n_m=n_m, bm=bm, fp8_max=fp8max,
                          q_dtype=q_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e, k, n), jnp.float32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(group_sizes, qx, sexp, qg)
