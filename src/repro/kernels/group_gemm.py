"""Pallas TPU kernel: per-group FP8 GEMM — the COAT baseline of paper
Fig 3a, implemented for the GEMM-efficiency ablation (paper Table 6).

y[m, n] = Σ_g ( Σ_{k∈g} Qx[m, k] · Qw[k, n] ) · s_x[m, g]

The per-128-group f32 scales sit along the GEMM inner dimension, so
every K-block's partial sum must be rescaled on the VPU *inside* the
accumulation loop: an O(bm·bn) f32 multiply-add per K-block — K/bk of
them — versus MOSS's single epilogue multiply.  With bk = group = 128
and bm = bn = 128 that is 128× more in-loop VPU work per output element
than mx_gemm's operand rescale, which is the paper's core efficiency
argument restated for TPU (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.jaxapi import pallas_tpu_compiler_params

GROUP = 128


def _group_gemm_kernel(qx_ref, sx_ref, qw_ref, o_ref, acc_ref, *,
                       n_k: int, groups_per_block: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = qx_ref[...].astype(jnp.bfloat16)                  # (bm, bk)
    w = qw_ref[...].astype(jnp.bfloat16)                  # (bk, bn)
    bm = x.shape[0]
    if groups_per_block == 1:
        partial = jnp.dot(x, w, preferred_element_type=jnp.float32)
        # in-loop dequant: O(bm·bn) f32 multiply per K-block (the cost
        # MOSS's two-level scheme removes from the main loop)
        acc_ref[...] += partial * sx_ref[...]             # (bm,1) bcast
    else:
        bk = x.shape[1]
        g = bk // groups_per_block
        xg = x.reshape(bm, groups_per_block, g)
        for gi in range(groups_per_block):
            partial = jnp.dot(xg[:, gi], w[gi * g:(gi + 1) * g],
                              preferred_element_type=jnp.float32)
            acc_ref[...] += partial * sx_ref[:, gi][:, None]

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def group_gemm_pallas(qx, sx, qw, *, bm: int = 128, bn: int = 128,
                      bk: int = GROUP, interpret: bool = False):
    """qx: (M, K) fp8; sx: (M, K//128) f32 group scales; qw: (K, N) fp8.
    Returns f32 accumulation scaled by the activation group scales;
    the caller applies the per-tensor weight scale."""
    m, k = qx.shape
    n = qw.shape[1]
    assert k % GROUP == 0 and sx.shape == (m, k // GROUP)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % GROUP == 0 or GROUP % bk == 0
    gpb = max(bk // GROUP, 1)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_group_gemm_kernel, n_k=n_k,
                          groups_per_block=gpb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, gpb), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qx, sx, qw)
