# Pallas TPU kernels for the MOSS hot path + the unified dispatch layer.
#
#   dispatch.py    backend selection (pallas / interpret / ref) — the
#                  single entry point for every quantized GEMM; the
#                  custom-VJP in repro.core.linear routes through it
#   mx_fused.py    fused two-level quantize + GEMM (fwd and bwd-dx)
#   mx_gemm.py     microscaled GEMM on pre-quantized operands
#   mx_bwd.py      dW GEMM: fused dequant → transpose → requant along M
#   moe_gmm.py     grouped-expert ragged GEMM (MoE): fused quantize +
#                  all expert GEMMs in one launch + the grouped dW
#   mx_quant.py    standalone fused two-level quantizer
#   decode_attn.py fused decode attention over the fp8/bf16 KV cache
#                  (scale application + ring masking + softmax +
#                  combine in one launch — the serving hot path)
#   group_gemm.py  COAT per-group baseline (in-loop dequant)
#   ref.py         pure-jnp oracles (semantics live in repro.core.quant)
#   ops.py         thin public wrappers over dispatch
