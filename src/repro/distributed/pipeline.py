"""GPipe-style pipeline parallelism over the ``pod`` axis.

The multi-pod mesh's pod axis defaults to pure data-parallel; this
module provides the alternative mapping: each pod holds a contiguous
slice of layers (a *stage*), microbatches stream through stages with
``jax.lax.ppermute`` moving activations pod-to-pod, and the classic
GPipe schedule (fill, steady state, drain) is expressed as one
``lax.scan`` over ``n_micro + n_stages - 1`` ticks.

Implemented with shard_map over ("pod",): inside, each device executes
its own stage's layer stack (params arrive pod-sharded along the stacked
layer axis).  Forward-only here — the framework's default remains
DP-over-pods for training (DESIGN.md §4); the pipeline path exists for
inference/scale-out experiments and compiles in the multi-pod dry-run
(tests/test_pipeline.py).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat.jaxapi import shard_map


def pipeline_forward(mesh, stage_fn, params_stacked, x_micro,
                     *, n_stages: int):
    """Run ``stage_fn(stage_params, x) -> x`` as a pipeline over pods.

    params_stacked: pytree with leading dim n_stages (stage-major layer
    stacks), sharded P("pod", ...).
    x_micro: (n_micro, mb, ...) microbatched activations, replicated.
    Returns (n_micro, mb, ...) outputs (from the last stage).
    """
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def body(p_stage, xm):
        # inside shard_map: p_stage is THIS pod's stage params (leading
        # stage dim of size 1), xm the full microbatch stream.
        p_stage = jax.tree.map(lambda a: a[0], p_stage)
        stage_id = jax.lax.axis_index("pod")
        buf = jnp.zeros_like(xm[0])
        outs = jnp.zeros_like(xm)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            take = jnp.clip(t, 0, n_micro - 1)
            buf = jnp.where(stage_id == 0, xm[take], buf)
            y = stage_fn(p_stage, buf)
            # pass activations to the next stage
            y_next = jax.lax.ppermute(
                y, "pod",
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage emits microbatch t-(n_stages-1)
            emit = t - (n_stages - 1)
            emit_ok = (emit >= 0) & (stage_id == n_stages - 1)
            slot = jnp.clip(emit, 0, n_micro - 1)
            outs = jnp.where(
                emit_ok,
                jax.lax.dynamic_update_index_in_dim(
                    outs, y, slot, 0),
                outs)
            return (y_next, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(ticks))
        # only the last stage's outs are real — zero the rest and psum
        # so the result is replicated over pods
        outs = jnp.where(stage_id == n_stages - 1, outs,
                         jnp.zeros_like(outs))
        return jax.lax.psum(outs, "pod")

    spec_p = jax.tree.map(lambda _: P("pod"), params_stacked)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec_p, P()), out_specs=P(),
        check_vma=False,
    )(params_stacked, x_micro)
