"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names; the active rule
set maps them to physical mesh axes.  Rules drop axes that don't divide
evenly (e.g. musicgen's 24 heads on a 16-way model axis) instead of
failing, so one model definition serves every mesh.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat.jaxapi import abstract_mesh

# logical name -> tuple of candidate mesh axes (joined as a tuple spec
# entry).  "batch" spans pod+data so the pod axis is pure DP.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),                  # replicated by default
    "kv_seq": ("model",),       # decode KV caches shard their seq dim
    "embed": (),                # activation d_model: replicated
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_tokens": ("pod", "data"),
    "fsdp": ("data",),          # weight dim sharded for ZeRO-3
    "lru": ("model",),
    "conv": (),
    "latent": (),               # MLA kv_lora dim
    "layers": (),               # stacked-layer leading axis
    "tokens_ep": ("pod", "data", "model"),  # MoE token parallelism
}

_state = threading.local()


def _rules() -> dict[str, tuple[str, ...]]:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def sharding_rules(overrides: dict[str, tuple[str, ...]] | None = None):
    old = _rules()
    merged = dict(old)
    if overrides:
        merged.update(overrides)
    _state.rules = merged
    try:
        yield
    finally:
        _state.rules = old


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate a mesh for logical-axis sharding AND as the jax mesh
    context (collectives, shard_map).  The framework's single entry
    point for mesh scoping."""
    old = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = old


def _active_mesh() -> Mesh | None:
    mesh = getattr(_state, "mesh", None)
    if mesh is not None:
        return mesh
    return abstract_mesh()


def resolve_spec(logical: tuple[str | None, ...],
                 mesh: Mesh,
                 dims: tuple[int, ...] | None = None) -> P:
    """Map logical names to a PartitionSpec, dropping axes that are
    missing from the mesh or that don't divide the dim size."""
    rules = _rules()
    entries = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        if name is None:
            entries.append(None)
            continue
        axes = []
        shards = 1
        for ax in rules.get(name, ()):
            if ax not in mesh.axis_names or ax in used:
                continue
            # greedy: take each axis only while divisibility holds
            if dims is not None and dims[i] % (shards
                                               * mesh.shape[ax]) != 0:
                continue
            axes.append(ax)
            shards *= mesh.shape[ax]
        if not axes:
            entries.append(None)
            continue
        for ax in axes:
            used.add(ax)
        entries.append(tuple(axes) if len(axes) > 1 else axes[0])
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(tuple(logical), mesh, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, logical: tuple[str | None, ...],
                   dims: tuple[int, ...] | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical, mesh, dims))
