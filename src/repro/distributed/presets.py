"""Named sharding presets — the §Perf hillclimbing knobs.

A preset is a logical-rule override set applied via
``sharding_rules(...)`` around lowering.  The same model definition
recompiles under any preset; the dry-run records which one produced
each artifact.

  2d        (baseline) Megatron-style: batch on (pod,data), TP on model
            (heads/mlp/vocab/experts), params FSDP on data x TP on model.
  fsdp      ZeRO-3-dominant: batch over EVERY mesh axis (pure DP for the
            compute), params fully sharded over (data, model); no
            activation TP traffic — per-layer weight all-gathers instead.
  tp-sp     2d + Megatron sequence parallelism: the residual stream is
            sequence-sharded on the model axis between blocks, so norms/
            elementwise run 1/16th and the per-layer activation carry
            shrinks 16x; GSPMD turns the TP all-reduces into
            all-gather + reduce-scatter pairs.
"""

from __future__ import annotations

PRESETS: dict[str, dict[str, tuple[str, ...]]] = {
    "2d": {},
    "fsdp": {
        "batch": ("pod", "data", "model"),
        "heads": (),
        "kv_heads": (),
        "mlp": (),
        "vocab": (),
        "lru": (),
        "fsdp": ("data", "model"),
        # experts keep the model axis: the EP shard_map path addresses
        # mesh axes directly and tokens are already split over all axes
        "experts": ("model",),
        "kv_seq": ("model",),
    },
    "tp-sp": {
        "seq": ("model",),
    },
}


def preset_rules(name: str) -> dict[str, tuple[str, ...]]:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; known: {list(PRESETS)}")
    return PRESETS[name]
