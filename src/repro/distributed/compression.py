"""FP8-compressed gradient all-reduce with error feedback (paper §4.4 /
Table 5: communication-volume reduction).

Each gradient leaf is per-tensor-scaled to E5M2, the quantized payload
is all-reduced across the DP axes, and the local quantization residual
is carried to the next step (error feedback → unbiased over time;
convergence test in tests/test_training.py).

Two wire modes:
  - "fp8_psum" (default): the E5M2 values are carried in bf16 for the
    psum (E5M2 ⊂ bf16, so the cast is exact).  2 bytes/element on the
    wire — half of f32 master grads, and the summation is robust.  This
    is the deployable variant on today's ICI.
  - "fp8_gather": all-gather of the raw 1-byte E5M2 payload + local
    reduction.  Shows true 8-bit collective bytes in the HLO; memory is
    n_shards× the leaf, so it is for benchmarks/small models.

The paper's BF16 baseline all-reduces bf16 grads; MOSS's measured 1.4×
volume saving (Table 5) comes from fp8 payloads plus fp8 activation
all-gathers under ZeRO — our roofline benchmark reproduces the grad
part of that accounting.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat.jaxapi import shard_map
from repro.core.quant import quant_per_tensor


def init_residuals(params):
    return jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)


def _dp_axes(mesh, dp_axes):
    return tuple(a for a in dp_axes if a in mesh.axis_names)


def fp8_allreduce_grads(grads, residuals, mesh, dp_axes=("pod", "data"),
                        mode: str = "fp8_psum"):
    """Returns (reduced_grads, new_residuals)."""
    axes = _dp_axes(mesh, dp_axes)
    if not axes:
        return grads, residuals

    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def body(g_loc, r_loc):
        gf = g_loc.astype(jnp.float32) + r_loc
        q = quant_per_tensor(gf, "e5m2")
        new_r = gf - q.dequant()
        if mode == "fp8_gather":
            payload = jax.lax.all_gather(q.q, axes)        # 1B/elt wire
            scales = jax.lax.all_gather(q.s, axes)
            tot = jnp.sum(payload.astype(jnp.float32)
                          * scales.reshape((-1,) + (1,) * g_loc.ndim),
                          axis=0)
            red = tot / n
        else:
            carried = q.q.astype(jnp.bfloat16)             # exact cast
            tot = jax.lax.psum(carried.astype(jnp.float32) * q.s, axes)
            red = tot / n
        return red.astype(g_loc.dtype), new_r

    def one(g, r):
        return shard_map(body, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P()), check_vma=False)(g, r)

    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(g_leaves, r_leaves)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
