"""Attention: GQA/MQA/MHA with RoPE, full/sliding-window/local variants,
flash-style chunked softmax (never materializes S×S), and a KV cache
with ring-buffer semantics for window attention.

All projections are MOSS-quantized linears.  Scores/softmax run in f32
(the paper keeps non-GEMM ops in high precision).  Decode attention
routes through ``repro.kernels.dispatch.decode_attention`` — the fused
Pallas kernel over the fp8/bf16 cache by default, the scale-folding
einsum path under ``REPRO_DECODE_ATTN=einsum``
(docs/decode-attention.md).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import QuantConfig
from repro.core.linear import dense_general
from repro.core.runtime_flags import decode_attn_path
from repro.distributed.sharding import shard
from repro.kernels import dispatch
from repro.core.runtime_flags import einsum as rf_einsum
from .layers import PDef, apply_rope
from ._attn_core import NEG_INF, chunked_attention, _window


class KVCache(NamedTuple):
    """Decode KV cache, kv-head-major.

    Layout (one layer, pre-stacking; C = ``cache_len`` = min(max_len,
    window)):

      k, v      (B, KV, C, Dh)  payloads — e4m3 (fp8 cache, the
                                serving default) or bf16.  kv-head
                                major so the decode kernel reads
                                contiguous (C, Dh) tiles per
                                (batch, kv-head) with no transpose
      k_scale,  (B, KV, C)      f32 per-(token, kv-head) scales when
      v_scale                   fp8, else None — one scale per written
                                position's head vector (amax over Dh)
      idx       () | (B,)       int32: absolute position of the next
                                write (NOT mod C) — doubles as the
                                valid-token count: slot s holds a live
                                position iff s < min(idx, C).  Scalar:
                                one shared ring position for every
                                batch row (training-eval / legacy
                                serving).  Vector (``per_slot`` cache,
                                the continuous-batching engine): each
                                row tracks its own depth, so requests
                                with different prompt lengths coexist
                                (docs/continuous-batching.md)

    The fp8 layout halves the decode-step HBM read (the
    memory-roofline term that dominates decode cells —
    benchmarks/roofline.py); the scales add 4/Dh bytes/element.
    Scale convention: payload · scale reconstructs the stored vector;
    decode attention never materializes that product — the K scale
    folds into the score and the V scale into the combine weight
    (einsum path), or both fold inside the kernel (fused path).

    Ring append contract (``_cache_write``): position p lives in slot
    p % C; appends of S ≥ C positions keep the last C (prefill of a
    window cache), shorter appends write ``[idx % C, idx % C + S)``
    contiguously — the serving engine never wraps a multi-token append
    mid-stream (prefill starts at idx=0; decode appends S=1).

    Floating-page pool variant (``block_table`` not None —
    docs/paged-attention.md): the payload leaves change meaning to a
    GLOBAL pool shared by every slot —

      k, v          (P, KV, T, Dh)  P physical pages of T tokens
      k/v_scale     (P, KV, T)      per-(token, kv-head) scales (fp8)
      idx           (B,)            per-slot logical depth, as before
      block_table   (B, NP)         int32: logical page j of slot b is
                                    physical row ``block_table[b, j]``
                                    (NP = pages_per_slot = C/T)

    Decode attention gathers pages through the block table
    (``dispatch.decode_attention_paged``); a decode append writes one
    position into page ``block_table[b, idx[b]//T]`` at offset
    ``idx[b] % T``.  Ring semantics don't apply (the engine gates
    float mode to non-windowed families)."""

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None
    v_scale: jax.Array | None
    idx: jax.Array
    block_table: jax.Array | None = None


def _quant_kv(x):
    """(B, KV, S, Dh) -> (e4m3 payload, per-(B, KV, S) f32 scale).
    One amax over each position's head vector; TINY-clamped so zero
    vectors quantize to q=0 with a finite scale."""
    from repro.core.formats import E4M3_MAX, TINY, cast_fp8

    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(amax, TINY) / E4M3_MAX
    q = cast_fp8(x.astype(jnp.float32) / s[..., None], "e4m3")
    return q, s


def _dequant_kv(q, s, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


def attn_defs(cfg):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    defs = {
        "wq": PDef((d, h, dh), ("fsdp", "heads", None), quantized=True),
        "wk": PDef((d, kv, dh), ("fsdp", "kv_heads", None), quantized=True),
        "wv": PDef((d, kv, dh), ("fsdp", "kv_heads", None), quantized=True),
        "wo": PDef((h, dh, d), ("heads", None, "fsdp"), quantized=True),
    }
    if cfg.qk_norm:
        defs["q_norm"] = PDef((dh,), (None,), "ones")
        defs["k_norm"] = PDef((dh,), (None,), "ones")
    return defs


def cache_len(cfg, max_len: int) -> int:
    w = _window(cfg)
    return min(max_len, w) if w else max_len


def resolve_kv_cache_dtype(cfg) -> str:
    """Active KV-cache storage dtype: ``REPRO_KV_CACHE`` env override,
    else the per-arch config (default "fp8" — decode is memory-bound
    and the fp8 cache halves the dominant HBM-read term; docs/
    serving.md).  Only consulted at cache *init*: an existing cache
    keeps its layout.  MLA's absorbed latent cache ignores this (it is
    already ~an order of magnitude smaller than per-head K/V)."""
    from repro.core.runtime_flags import kv_cache_override

    return kv_cache_override() or cfg.kv_cache_dtype


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> KVCache:
    """Builds the shared-scalar-``idx`` cache; the serving engine's
    per-slot variant (``idx`` as a (B,) vector) is produced by
    ``transformer.init_caches(per_slot=True)``, which widens the idx
    of every cache node in one place."""
    c = cache_len(cfg, max_len)
    shape = (batch, cfg.n_kv, c, cfg.head_dim)
    idx = jnp.zeros((), jnp.int32)
    if resolve_kv_cache_dtype(cfg) == "fp8":
        return KVCache(k=jnp.zeros(shape, jnp.float8_e4m3fn),
                       v=jnp.zeros(shape, jnp.float8_e4m3fn),
                       k_scale=jnp.zeros(shape[:-1], jnp.float32),
                       v_scale=jnp.zeros(shape[:-1], jnp.float32),
                       idx=idx)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   k_scale=None, v_scale=None, idx=idx)


def init_page_pool(cfg, num_pages: int, pages_per_slot: int,
                   batch: int, page_size: int,
                   dtype=jnp.bfloat16) -> KVCache:
    """Builds ONE layer's floating-page pool cache (pre-stacking):
    payload (P, KV, T, Dh) + scales (P, KV, T) shared by every slot,
    per-slot depth ``idx`` (B,) and ``block_table`` (B, NP) int32.
    Storage dtype follows ``resolve_kv_cache_dtype`` exactly like
    ``init_cache``.  Physical page contents are zero-initialized; a
    page is only ever read through a block table whose slot depth
    covers it, so stale retired-page bytes are masked out by the
    kernel's validity mask regardless."""
    shape = (num_pages, cfg.n_kv, page_size, cfg.head_dim)
    idx = jnp.zeros((batch,), jnp.int32)
    bt = jnp.zeros((batch, pages_per_slot), jnp.int32)
    if resolve_kv_cache_dtype(cfg) == "fp8":
        return KVCache(k=jnp.zeros(shape, jnp.float8_e4m3fn),
                       v=jnp.zeros(shape, jnp.float8_e4m3fn),
                       k_scale=jnp.zeros(shape[:-1], jnp.float32),
                       v_scale=jnp.zeros(shape[:-1], jnp.float32),
                       idx=idx, block_table=bt)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   k_scale=None, v_scale=None, idx=idx, block_table=bt)


def cache_logical(cfg) -> KVCache:
    """Logical sharding axes for ONE layer's cache (pre-stacking).
    The seq dim carries the model axis when kv_heads can't (resolve_spec
    drops whichever doesn't divide)."""
    kv = ("batch", "kv_heads", "kv_seq", None)
    sc = ("batch", "kv_heads", "kv_seq")
    fp8 = resolve_kv_cache_dtype(cfg) == "fp8"
    return KVCache(k=kv, v=kv, k_scale=sc if fp8 else None,
                   v_scale=sc if fp8 else None, idx=())


def _qk_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))


def _project_qkv(cfg, p, x, positions, qcfg: QuantConfig):
    q = dense_general(x, p["wq"], qcfg)                  # (B,S,H,Dh)
    k = dense_general(x, p["wk"], qcfg)                  # (B,S,KV,Dh)
    v = dense_general(x, p["wv"], qcfg)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps).astype(x.dtype)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps).astype(x.dtype)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _decode_attention(cfg, q, cache: KVCache, n_valid):
    """Single-step attention against the cache.

    q: (B,1,H,Dh).  GQA grouping: head h belongs to kv head h // G
    (G = H // KV), so the (B, KV, G, Dh) regroup is a free reshape.
    Routed through ``dispatch.decode_attention`` — fused Pallas kernel
    on the pallas/interpret backends, the scale-folding einsum oracle
    on ref; ``REPRO_DECODE_ATTN=einsum`` pins the einsum path."""
    b, _, h, dh = q.shape
    kvh = cache.k.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh)
    backend = "ref" if decode_attn_path() == "einsum" else None
    if cache.block_table is not None:
        out = dispatch.decode_attention_paged(
            qg, cache.k, cache.v, cache.k_scale, cache.v_scale,
            n_valid, cache.block_table, sm_scale=dh ** -0.5,
            backend=backend)
    else:
        out = dispatch.decode_attention(
            qg, cache.k, cache.v, cache.k_scale, cache.v_scale, n_valid,
            sm_scale=dh ** -0.5, backend=backend)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def _verify_attention(cfg, q, cache: KVCache, n_valid):
    """Speculative verify: S draft queries per slot against the cache
    the drafts were just written into, in ONE fused step.

    q: (B,S,H,Dh); ``n_valid`` is the POST-write depth (every entry
    ≥ S).  The (B, KV, S, G, Dh) regroup feeds the batched-query 5-D
    entry of ``dispatch.decode_attention{,_paged}``: draft j attends
    ``slot < n_valid[b] - (S-1-j)`` — its own freshly-written position
    and everything before it, but no later draft's — so row j computes
    EXACTLY what sequential decode step j would, and greedy
    accept/reject on the outputs is token-for-token exact
    (docs/speculative-decoding.md).  Unlike ``_chunk_attention`` the
    history is never dequantized in HBM: the same fused-kernel /
    scale-folding-einsum contract as single-token decode applies, so
    the verify jaxpr keeps 0 cache-sized upcasts/dots."""
    b, s, h, dh = q.shape
    kvh = cache.k.shape[1]
    g = h // kvh
    # (B,S,H,Dh) -> (B,KV,S,G,Dh): head h of draft j is (kv h//G, g h%G)
    qg = q.reshape(b, s, kvh, g, dh).transpose(0, 2, 1, 3, 4)
    backend = "ref" if decode_attn_path() == "einsum" else None
    if cache.block_table is not None:
        out = dispatch.decode_attention_paged(
            qg, cache.k, cache.v, cache.k_scale, cache.v_scale,
            n_valid, cache.block_table, sm_scale=dh ** -0.5,
            backend=backend)
    else:
        out = dispatch.decode_attention(
            qg, cache.k, cache.v, cache.k_scale, cache.v_scale, n_valid,
            sm_scale=dh ** -0.5, backend=backend)
    out = out.transpose(0, 2, 1, 3, 4).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def _chunk_attention(cfg, q, k_new, v_new, cache: KVCache, pos0):
    """Chunked-prefill attention: S new prompt tokens at each slot's
    depth against the already-resident history plus an in-chunk causal
    mask (docs/continuous-batching.md).

    q: (B,S,H,Dh); k_new/v_new: the chunk's pre-quantization bf16
    K/V in projection layout (B,S,KV,Dh) — the values ``_cache_write``
    just appended.  ``pos0`` is the PRE-write depth: history positions
    ``< pos0[b]`` are read back from the (post-write) cache — paged
    caches gather each slot's pages through the block table — so fresh
    and garbage-padded positions (all ≥ pos0) are masked regardless of
    content, while the chunk's diagonal block attends its exact bf16
    values, matching whole-prompt prefill's treatment.  One combined
    f32 softmax over history + chunk.  fp8 caches read history back
    dequantized (the accepted chunked-vs-whole difference; bf16 caches
    read back the exact original bytes).  Non-windowed families only
    (``transformer.chunk_prefill_supported``) — history positions are
    absolute, never ring-wrapped."""
    b, s, h, dh = q.shape
    kvh = k_new.shape[2]
    g = h // kvh
    scale = dh ** -0.5
    fp8 = cache.k_scale is not None
    pos0 = jnp.broadcast_to(jnp.atleast_1d(pos0), (b,))

    if cache.block_table is not None:
        def gather(pool):                     # (P,KV,T,...) -> (B,KV,C,...)
            x = pool[cache.block_table]       # (B,NP,KV,T,...)
            x = jnp.moveaxis(x, 2, 1)         # (B,KV,NP,T,...)
            return x.reshape(b, kvh, -1, *x.shape[4:])

        kh, vh = gather(cache.k), gather(cache.v)
        ksh = gather(cache.k_scale) if fp8 else None
        vsh = gather(cache.v_scale) if fp8 else None
    else:
        kh, vh = cache.k, cache.v
        ksh, vsh = cache.k_scale, cache.v_scale
    if fp8:
        kh = _dequant_kv(kh, ksh)
        vh = _dequant_kv(vh, vsh)
    c = kh.shape[2]

    qg = q.reshape(b, s, kvh, g, dh).transpose(0, 2, 3, 1, 4)
    kf = k_new.transpose(0, 2, 1, 3)          # (B,KV,S,Dh)
    vf = v_new.transpose(0, 2, 1, 3)

    s_hist = rf_einsum("bkgsd,bkcd->bkgsc", qg, kh) * scale
    s_self = rf_einsum("bkgsd,bktd->bkgst", qg, kf) * scale
    hist_ok = jnp.arange(c, dtype=jnp.int32)[None, :] < pos0[:, None]
    s_hist = jnp.where(hist_ok[:, None, None, None, :], s_hist, NEG_INF)
    causal = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    s_self = jnp.where(causal[None, None, None], s_self, NEG_INF)
    scores = jnp.concatenate([s_hist, s_self], axis=-1)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = (rf_einsum("bkgsc,bkcd->bkgsd", p[..., :c], vh)
           + rf_einsum("bkgst,bktd->bkgsd", p[..., c:], vf))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def _cache_write(cfg, cache: KVCache, k_new, v_new) -> KVCache:
    """Append S_new positions (prefill: many; decode: 1) with ring
    semantics for window attention; fp8 caches quantize on write.

    ``k_new``/``v_new`` arrive in projection layout (B, S, KV, Dh) and
    are transposed once to the cache's kv-head-major layout — a
    prompt-sized copy at prefill, a single position at decode; the
    cache itself is only ever written in place."""
    fp8 = cache.k_scale is not None
    k_new = k_new.transpose(0, 2, 1, 3)                   # (B,KV,S,Dh)
    v_new = v_new.transpose(0, 2, 1, 3)
    if fp8:
        k_new, ks_new = _quant_kv(k_new)
        v_new, vs_new = _quant_kv(v_new)
    c = cache.k.shape[2]
    s_new = k_new.shape[2]

    if cache.block_table is not None:
        # floating-page pool: position p lands in physical page
        # block_table[b, p // T] at in-page offset p % T.  The engine
        # guarantees every target page is writable (refcount 1) via
        # copy-on-write BEFORE the step, so a scatter here never
        # aliases a shared page.  Advanced indices (page, off) with the
        # interior ':' put the batch dim first → (B[, S], KV, ...)
        # updates.
        assert cache.idx.ndim == 1, "paged cache uses per-slot depths"
        t = cache.k.shape[2]
        if s_new == 1:
            pos = cache.idx
            page = jnp.take_along_axis(
                cache.block_table, (pos // t)[:, None], axis=1)[:, 0]
            off = pos % t

            def put(pool, upd):
                return pool.at[page, :, off].set(upd.astype(pool.dtype))

            return cache._replace(
                k=put(cache.k, k_new[:, :, 0]),
                v=put(cache.v, v_new[:, :, 0]),
                k_scale=put(cache.k_scale, ks_new[:, :, 0]) if fp8
                else None,
                v_scale=put(cache.v_scale, vs_new[:, :, 0]) if fp8
                else None,
                idx=cache.idx + 1)

        # chunked prefill: S positions from each slot's depth into its
        # own pages.  Padded tail positions past the block-table width
        # are redirected to the pool's TRASH row (the one extra
        # physical page init_paged_pools allocates; explicit where —
        # a clipped gather would hit the request's own LAST real
        # page); in-table entries that aren't assigned yet already
        # hold the trash row id (the engine restamps them).  Trash
        # bytes are never read: history masking is `< pos0` and
        # n_valid never covers them.
        n_pages = cache.block_table.shape[1]
        trash = cache.k.shape[0] - 1
        pos = cache.idx[:, None] + jnp.arange(s_new, dtype=jnp.int32)
        lp = pos // t
        page = jnp.where(
            lp < n_pages,
            jnp.take_along_axis(cache.block_table,
                                jnp.clip(lp, 0, n_pages - 1), axis=1),
            trash)
        off = pos % t

        def put_s(pool, upd):                 # upd (B,KV,S,...)
            u = jnp.moveaxis(upd, 2, 1).astype(pool.dtype)
            return pool.at[page, :, off].set(u)

        return cache._replace(
            k=put_s(cache.k, k_new), v=put_s(cache.v, v_new),
            k_scale=put_s(cache.k_scale, ks_new) if fp8 else None,
            v_scale=put_s(cache.v_scale, vs_new) if fp8 else None,
            idx=cache.idx + s_new)

    if cache.idx.ndim == 1 and s_new > 1:
        # per-slot chunked-prefill append (identity placement): each
        # row writes S positions at its own depth.  Advanced-index
        # scatter with mode="drop" so a chunk's padded tail positions
        # (≥ C) vanish instead of clamping onto live slots
        # (dynamic_update_slice CLAMPS start indices).  No ring
        # semantics: the engine gates chunked prefill to non-windowed
        # families (C == max_len).
        pos = cache.idx[:, None] + jnp.arange(s_new, dtype=jnp.int32)
        b_idx = jnp.arange(cache.k.shape[0])[:, None]

        def put_p(buf, upd):                  # upd (B,KV,S,...)
            u = jnp.moveaxis(upd, 2, 1).astype(buf.dtype)
            return buf.at[b_idx, :, pos].set(u, mode="drop")

        return KVCache(put_p(cache.k, k_new), put_p(cache.v, v_new),
                       put_p(cache.k_scale, ks_new) if fp8 else None,
                       put_p(cache.v_scale, vs_new) if fp8 else None,
                       cache.idx + s_new)

    if s_new >= c:
        # keep the last C positions (prefill of a window cache);
        # ring layout: position p lives in slot p % C.  Never reached
        # with a per-slot idx vector: the engine prefills one request
        # at a time into a fresh scalar-idx cache and merges rows.
        assert cache.idx.ndim == 0, "multi-token ring append needs a " \
            "shared scalar idx (engine prefills per request)"
        start = (cache.idx + s_new - c) % c
        roll = lambda x: jnp.roll(x[:, :, -c:].astype(x.dtype), start,
                                  axis=2)
        return KVCache(roll(k_new).astype(cache.k.dtype),
                       roll(v_new).astype(cache.v.dtype),
                       roll(ks_new) if fp8 else None,
                       roll(vs_new) if fp8 else None,
                       cache.idx + s_new)
    start = cache.idx % c
    zero = jnp.zeros((), jnp.int32)

    if cache.idx.ndim == 1:
        # per-slot cache: every batch row writes at its own ring
        # position (decode slots at different depths).  vmap the
        # row-level dynamic_update_slice over the batched start —
        # lowers to a scatter (single-host serving; the SPMD caveat
        # below doesn't bite because the engine runs unsharded).
        assert s_new == 1, "per-slot cache appends decode one token"

        def dus_row(buf, upd, st):
            idxs = (zero, st) + (zero,) * (buf.ndim - 2)
            return jax.lax.dynamic_update_slice(
                buf, upd.astype(buf.dtype), idxs)

        dus_b = jax.vmap(dus_row, in_axes=(0, 0, 0))
        k = dus_b(cache.k, k_new, start)
        v = dus_b(cache.v, v_new, start)
        ks = dus_b(cache.k_scale, ks_new, start) if fp8 else None
        vs = dus_b(cache.v_scale, vs_new, start) if fp8 else None
        return KVCache(k, v, ks, vs, cache.idx + s_new)

    # contiguous in-place write (decode: one slot; prefill: [idx, idx+s))
    # via dynamic_update_slice — advanced-index scatter would lower to a
    # full-cache f32 select copy under SPMD.  Wraparound can only occur
    # for multi-token appends into a ring cache mid-stream, which the
    # serving engine never does (prefill starts at idx=0; decode s=1).
    def dus(buf, upd):
        idxs = (zero, zero, start) + (zero,) * (buf.ndim - 3)
        return jax.lax.dynamic_update_slice(buf, upd.astype(buf.dtype),
                                            idxs)

    k = dus(cache.k, k_new)
    v = dus(cache.v, v_new)
    ks = dus(cache.k_scale, ks_new) if fp8 else None
    vs = dus(cache.v_scale, vs_new) if fp8 else None
    return KVCache(k, v, ks, vs, cache.idx + s_new)


def attention(cfg, p, x, positions, qcfg: QuantConfig,
              cache: KVCache | None = None, mode: str = "train"):
    """Returns (out, new_cache).  Modes:
      train   — chunked causal attention, no cache
      prefill — chunked causal attention + cache fill
      decode  — S == 1: single new token against the cache (the fused
                kernel); S > 1: a chunked-prefill step — S prompt
                tokens appended at the slot's depth, attending history
                + an in-chunk causal mask (non-windowed families only;
                the engine gates this)
      verify  — speculative verify: S = k tokens ([last committed,
                drafts...]) written to the cache then attended in one
                fused batched-query step under the in-step causal
                mask.  S == 1 degenerates to exactly the decode path.
                Non-windowed, unwrapped caches only (the engine gates
                this; docs/speculative-decoding.md)
    """
    if mode in ("decode", "verify"):
        q, k_new, v_new = _project_qkv(cfg, p, x, positions, qcfg)
        if x.shape[1] == 1 or mode == "verify":
            new_cache = _cache_write(cfg, cache, k_new, v_new)
            n_valid = new_cache.idx
            out = (_decode_attention(cfg, q, new_cache, n_valid)
                   if x.shape[1] == 1
                   else _verify_attention(cfg, q, new_cache, n_valid))
        else:
            pos0 = cache.idx
            new_cache = _cache_write(cfg, cache, k_new, v_new)
            out = _chunk_attention(cfg, q, k_new, v_new, new_cache, pos0)
    else:
        q, k, v = _project_qkv(cfg, p, x, positions, qcfg)
        out = chunked_attention(cfg, q, k, v)
        new_cache = None
        if mode == "prefill":
            new_cache = _cache_write(
                cfg, init_cache(cfg, x.shape[0], cache.k.shape[2]
                                if cache is not None else x.shape[1]),
                k, v)
    out = shard(out, "batch", None, "heads", None)
    y = dense_general(out.reshape(*out.shape[:-2], -1),
                      QTflat(p["wo"]), qcfg)
    return shard(y, "batch", "seq", "embed"), new_cache


def QTflat(wt):
    """wo is stored (H, Dh, d); flatten to (H·Dh, d) for the GEMM.
    Preserves the activation-scale field (delayed-scale serving)."""
    from repro.core.linear import QT
    w = wt.w if hasattr(wt, "w") else wt
    s = wt.s if hasattr(wt, "s") else None
    return QT(w.reshape(-1, w.shape[-1]), s, getattr(wt, "a", None))
