"""Shared model components: parameter definitions, norms, RoPE,
activations, embeddings, FFNs — all linear layers route through the MOSS
quantized ``qlinear``.

Parameter system
----------------
``PDef`` is the single source of truth per parameter: shape, logical
sharding axes, initializer, and whether the tensor is a *quantized
linear weight* (participates in FP8 + automatic scaling).  From a pytree
of PDefs we derive materialized params, ShapeDtypeStructs (dry-run),
PartitionSpecs, and the autoscale mask.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import QuantConfig
from repro.core.linear import QT, qlinear
from repro.distributed.sharding import resolve_spec, shard


class PDef(NamedTuple):
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]   # logical sharding axes per dim
    init: str = "normal"              # normal | zeros | ones | embed | small
    quantized: bool = False           # FP8 linear weight (autoscaled)
    dtype: Any = jnp.float32


def is_pdef(x) -> bool:
    return isinstance(x, PDef)


def _fan_in(shape: tuple[int, ...]) -> int:
    return shape[0] if len(shape) > 1 else shape[0]


def init_param(key, d: PDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        return jax.random.normal(key, d.shape, d.dtype) * 0.02
    if d.init == "small":
        return jax.random.normal(key, d.shape, d.dtype) * 0.006
    # truncated-normal fan-in init for linear weights
    std = 1.0 / math.sqrt(max(_fan_in(d.shape), 1))
    return jax.random.truncated_normal(key, -2, 2, d.shape, d.dtype) * std


def init_tree(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_pdef)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [init_param(k, d) for k, d in zip(keys, leaves)])


def abstract_tree(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
        is_leaf=is_pdef)


def spec_tree(defs, mesh):
    return jax.tree.map(
        lambda d: resolve_spec(d.logical, mesh, d.shape), defs,
        is_leaf=is_pdef)


def quant_mask_tree(defs):
    return jax.tree.map(lambda d: d.quantized, defs, is_leaf=is_pdef)


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacked-layer dim to every PDef (scan-over-layers)."""
    return jax.tree.map(
        lambda d: PDef((n, *d.shape), (axis_name, *d.logical), d.init,
                       d.quantized, d.dtype),
        defs, is_leaf=is_pdef)


def wrap_qt(params, scales, mask):
    """Bundle quantized weights with their predicted scales: quantized
    leaves become QT(w, s); others stay raw arrays."""
    return jax.tree.map(
        lambda w, s, m: QT(w, s) if m else w, params, scales, mask)


def wrap_qt_nojit(params, mask):
    """QT-wrap without precomputed scales (jit scaling / eval)."""
    return jax.tree.map(lambda w, m: QT(w, None) if m else w, params, mask)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm_defs(cfg, d: int):
    if cfg.norm == "layernorm":
        return {"scale": PDef((d,), (None,), "ones"),
                "bias": PDef((d,), (None,), "zeros")}
    return {"scale": PDef((d,), (None,), "zeros")}   # rmsnorm (1+scale)


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Rotary / sinusoidal position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x, positions, theta: float = 1e4, pct: float = 1.0):
    """x: (..., S, H, Dh); positions: (..., S) int32.  Rotates the first
    ``pct`` fraction of head dims (partial rotary for stablelm)."""
    dh = x.shape[-1]
    rot = int(dh * pct)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = rope_frequencies(rot, theta)                      # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, r/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def sinusoidal_embedding(positions, d: int):
    """MusicGen-style sinusoidal position embedding: (..., S) -> (..., S, d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# FFN (dense) — SwiGLU / GeGLU / GELU-MLP / squared-ReLU
# ---------------------------------------------------------------------------


def ffn_defs(cfg, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    defs = {"w_up": PDef((d, f), ("fsdp", "mlp"), quantized=True),
            "w_down": PDef((f, d), ("mlp", "fsdp"), quantized=True)}
    if gated:
        defs["w_gate"] = PDef((d, f), ("fsdp", "mlp"), quantized=True)
    return defs


def apply_ffn(cfg, p, x, qcfg: QuantConfig):
    up = qlinear(x, p["w_up"], qcfg)
    if cfg.act == "swiglu":
        gate = qlinear(x, p["w_gate"], qcfg)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.act == "geglu":
        gate = qlinear(x, p["w_gate"], qcfg)
        h = jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(up.astype(jnp.float32))).astype(x.dtype)
    else:  # gelu_mlp
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", None, "mlp")
    return qlinear(h, p["w_down"], qcfg)


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------


def embed_defs(cfg):
    defs = {"embedding": PDef((cfg.vocab, cfg.d_model), ("vocab", "fsdp"),
                              "embed")}
    if not cfg.tie_embeddings:
        defs["head"] = PDef((cfg.d_model, cfg.vocab), ("fsdp", "vocab"),
                            quantized=True)
    return defs


def embed_tokens(cfg, p, tokens):
    emb = p["embedding"]
    emb = emb.w if isinstance(emb, QT) else emb
    x = jnp.take(emb, tokens, axis=0).astype(jnp.bfloat16)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), jnp.bfloat16)
    return shard(x, "batch", "seq", "embed")


def lm_head(cfg, p, x, qcfg: QuantConfig):
    if cfg.tie_embeddings:
        if "head_t" in p:
            # prequantized transposed head (serving): the fp8 payload
            # was cast at build time, so no vocab-sized quantize (or
            # its amax reduction) appears in the decode graph
            logits = qlinear(x, p["head_t"], qcfg)
        else:
            emb = p["embedding"]
            w = (emb.w if isinstance(emb, QT) else emb).T
            logits = qlinear(x, QT(w, None), qcfg)
    else:
        logits = qlinear(x, p["head"], qcfg)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
    return shard(logits.astype(jnp.float32), "batch", "seq", "vocab")
