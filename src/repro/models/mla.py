"""Multi-head Latent Attention (DeepSeek-V2), kv_lora_rank=512.

Train/prefill materialize per-head K/V from the latent; decode uses the
*absorbed* form so the cache is just (c_kv, k_rope) — (512+64) values
per token shared across all heads.  Projections are MOSS-quantized; the
tiny absorbed einsums stay bf16.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import QuantConfig
from repro.core.linear import QT, qlinear, dense_general
from repro.core.runtime_flags import einsum as rf_einsum
from repro.distributed.sharding import shard
from .layers import PDef, apply_rope, rmsnorm
from ._attn_core import chunked_attention


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, T, kv_lora)
    k_rope: jax.Array  # (B, T, q_rope)
    idx: jax.Array     # () shared write position, or (B,) per-slot
                       # lengths (continuous-batching engine — rows at
                       # different depths; docs/continuous-batching.md)


def mla_defs(cfg):
    d, h = cfg.d_model, cfg.n_heads
    dq = cfg.q_nope + cfg.q_rope
    return {
        "wq": PDef((d, h, dq), ("fsdp", "heads", None), quantized=True),
        "w_dkv": PDef((d, cfg.kv_lora), ("fsdp", "latent"), quantized=True),
        "w_kr": PDef((d, cfg.q_rope), ("fsdp", None), quantized=True),
        "kv_norm": PDef((cfg.kv_lora,), (None,), "zeros"),
        "w_uk": PDef((cfg.kv_lora, h, cfg.q_nope), ("latent", "heads", None),
                     quantized=True),
        "w_uv": PDef((cfg.kv_lora, h, cfg.v_head), ("latent", "heads", None),
                     quantized=True),
        "wo": PDef((h, cfg.v_head, d), ("heads", None, "fsdp"),
                   quantized=True),
    }


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    # per-slot idx (B,) is widened by transformer.init_caches(per_slot=)
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        k_rope=jnp.zeros((batch, max_len, cfg.q_rope), dtype),
        idx=jnp.zeros((), jnp.int32))


def cache_logical(cfg) -> MLACache:
    return MLACache(c_kv=("batch", "kv_seq", None),
                    k_rope=("batch", "kv_seq", None), idx=())


def _latent(cfg, p, x, positions, qcfg):
    c_kv = qlinear(x, p["w_dkv"], qcfg)                       # (B,S,512)
    c_kv = rmsnorm(c_kv, p["kv_norm"].w if isinstance(p["kv_norm"], QT)
                   else p["kv_norm"], cfg.norm_eps)
    k_r = qlinear(x, p["w_kr"], qcfg)[..., None, :]           # (B,S,1,64)
    k_r = apply_rope(k_r, positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_r


def _queries(cfg, p, x, positions, qcfg):
    q = dense_general(x, p["wq"], qcfg)                       # (B,S,H,192)
    q_n, q_r = q[..., :cfg.q_nope], q[..., cfg.q_nope:]
    q_r = apply_rope(q_r, positions, cfg.rope_theta)
    return q_n, q_r


def mla_attention(cfg, p, x, positions, qcfg: QuantConfig,
                  cache: MLACache | None = None, mode: str = "train"):
    b, s, _ = x.shape
    h = cfg.n_heads
    q_n, q_r = _queries(cfg, p, x, positions, qcfg)
    c_kv, k_r = _latent(cfg, p, x, positions, qcfg)

    if mode == "decode":
        t = cache.c_kv.shape[1]
        start = cache.idx % t
        zero = jnp.zeros((), jnp.int32)
        if cache.idx.ndim == 1:
            # per-slot cache: each batch row appends at its own depth
            dus_row = jax.vmap(
                lambda buf, upd, st: jax.lax.dynamic_update_slice(
                    buf, upd.astype(buf.dtype), (st, zero)),
                in_axes=(0, 0, 0))
            new_cache = MLACache(c_kv=dus_row(cache.c_kv, c_kv, start),
                                 k_rope=dus_row(cache.k_rope, k_r, start),
                                 idx=cache.idx + s)
        else:
            new_cache = MLACache(
                c_kv=jax.lax.dynamic_update_slice(
                    cache.c_kv, c_kv.astype(cache.c_kv.dtype),
                    (zero, start, zero)),
                k_rope=jax.lax.dynamic_update_slice(
                    cache.k_rope, k_r.astype(cache.k_rope.dtype),
                    (zero, start, zero)),
                idx=cache.idx + s)
        # absorbed decode: q_lat[b,h,L] = q_nope · W_uk
        q_lat = rf_einsum("bshn,lhn->bshl", q_n, p["w_uk"].w,
                          out_dtype=jnp.float32)
        scores = (rf_einsum("bshl,btl->bsht", q_lat, new_cache.c_kv,
                            out_dtype=jnp.float32)
                  + rf_einsum("bshr,btr->bsht", q_r, new_cache.k_rope,
                              out_dtype=jnp.float32))
        scores *= (cfg.q_nope + cfg.q_rope) ** -0.5
        nv = jnp.broadcast_to(new_cache.idx.reshape(-1), (b,))
        valid = jnp.arange(t)[None, :] < jnp.minimum(nv, t)[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        ctx_lat = rf_einsum("bsht,btl->bshl", w, new_cache.c_kv,
                            out_dtype=jnp.float32)            # (B,1,H,512)
        out = rf_einsum("bshl,lhv->bshv", ctx_lat, p["w_uv"].w,
                        out_dtype=jnp.float32).astype(x.dtype)
    else:
        # materialized K/V per head for chunked attention
        k_n = dense_general(c_kv, p["w_uk"], qcfg)            # (B,S,H,128)
        v = dense_general(c_kv, p["w_uv"], qcfg)              # (B,S,H,128)
        k = jnp.concatenate(
            [k_n, jnp.broadcast_to(k_r[:, :, None, :], (b, s, h, cfg.q_rope))],
            axis=-1)
        q = jnp.concatenate([q_n, q_r], axis=-1)
        q = shard(q, "batch", None, "heads", None)
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
        out = chunked_attention(cfg, q, k, v)
        new_cache = None
        if mode == "prefill":
            fresh = init_mla_cache(cfg, b, cache.c_kv.shape[1]
                                   if cache is not None else s)
            zero = jnp.zeros((), jnp.int32)
            new_cache = MLACache(
                c_kv=jax.lax.dynamic_update_slice(
                    fresh.c_kv, c_kv.astype(fresh.c_kv.dtype),
                    (zero, zero, zero)),
                k_rope=jax.lax.dynamic_update_slice(
                    fresh.k_rope, k_r.astype(fresh.k_rope.dtype),
                    (zero, zero, zero)),
                idx=jnp.asarray(s, jnp.int32))

    wo = p["wo"]
    y = qlinear(out.reshape(b, s, -1),
                QT(wo.w.reshape(-1, cfg.d_model), wo.s), qcfg)
    return shard(y, "batch", "seq", "embed"), new_cache
