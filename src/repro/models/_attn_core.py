"""Shared flash-style chunked-attention core (used by GQA and MLA)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.runtime_flags import einsum as rf_einsum

NEG_INF = -1e30


def _window(cfg):
    if cfg.attn_type in ("swa", "local"):
        return cfg.window
    return None


def chunked_attention(cfg, q, k, v, q_pos0: int = 0):
    """Flash-style causal attention.

    q: (B,S,H,Dh); k,v: (B,T,KV,Dh).  Outer lax.map over query chunks,
    inner lax.scan over KV chunks with an online softmax — peak score
    memory is (B, Cq, H, Ck) instead of (B, S, H, T).
    """
    b, s, h, dh = q.shape
    dv = v.shape[-1]                     # may differ from dh (MLA)
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    window = _window(cfg)
    scale = dh ** -0.5

    cq = min(cfg.attn_chunk, s)
    ck = min(cfg.attn_chunk, t)
    nq, nk = -(-s // cq), -(-t // ck)
    q = jnp.pad(q, ((0, 0), (0, nq * cq - s), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * ck - t), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * ck - t), (0, 0), (0, 0)))
    # repeat kv->h heads (GQA); fused per-chunk below to bound memory
    kc = k.reshape(b, nk, ck, kvh, dh)
    vc = v.reshape(b, nk, ck, kvh, dv)
    qc = q.reshape(b, nq, cq, h, dh)

    q_positions = q_pos0 + jnp.arange(nq * cq).reshape(nq, cq)
    k_positions = jnp.arange(nk * ck).reshape(nk, ck)
    t_valid = t  # mask out kv padding

    def q_chunk(args):
        qi, qpos = args                                  # (B,Cq,H,Dh),(Cq,)

        @jax.checkpoint
        def kv_step(carry, xs):
            m, l, acc = carry
            kj, vj, kpos = xs                            # (B,Ck,KV,Dh),(Ck,)
            kj = jnp.repeat(kj, g, axis=2)               # (B,Ck,H,Dh)
            vj = jnp.repeat(vj, g, axis=2)
            scores = rf_einsum("bqhd,bkhd->bhqk", qi, kj,
                               out_dtype=jnp.float32) * scale
            mask = qpos[:, None] >= kpos[None, :]        # causal
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            mask &= (kpos < t_valid)[None, :]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            p_ = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = (acc * corr[..., None]
                       + rf_einsum("bhqk,bkhd->bhqd", p_, vj,
                                   out_dtype=jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             k_positions))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,H,Cq,Dh)
        return out.transpose(0, 2, 1, 3)                 # (B,Cq,H,Dh)

    # checkpoint both loop levels: scan/map autodiff otherwise stacks the
    # per-step softmax residuals into an (nq, nk, B, H, Cq, Ck) tensor —
    # the flash-attention backward instead recomputes scores per chunk.
    outs = jax.lax.map(jax.checkpoint(q_chunk),
                       (qc.transpose(1, 0, 2, 3, 4), q_positions))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * cq, h, dv)
    return out[:, :s].astype(q.dtype)


