"""Token-choice top-k Mixture of Experts with explicit expert parallelism.

Train/prefill path (mesh present): shard_map over (pod, data, model) —
tokens are split across *all* mesh axes for dispatch, experts live on
the ``model`` axis, and two ``all_to_all`` collectives move token
buffers to/from their experts (the torch-EP pattern, expressed
jax-natively; the collectives land in the HLO where the roofline
collective term can count them).

Dispatch is sort-based (argsort by expert id + capacity truncation) —
never materializes a (T, E, C) one-hot.  Per-device buffer is
(E, C_local, d) with C_local = ceil(T_local·k·cf/E).

Decode path (T small): masked dense-experts combine — every expert runs
on every token.  With batch≥experts·top_k the full expert weights are
read anyway, so the memory roofline is identical and decode stays
simple and shardable (DESIGN.md §3).

Router stays f32 and unquantized (tiny, accuracy-critical).  Expert
GEMMs are MOSS-quantized with *per-expert* weight scales.

Expert-GEMM execution (``REPRO_MOE_EXPERTS``, see
``repro.core.runtime_flags.moe_expert_path``):

  grouped  (default, moss mode)  the flat ``(E·C, d)`` dispatch buffer
           plus the ragged per-expert row counts (already produced by
           the sort-based dispatch) go through ONE grouped Pallas
           kernel per GEMM (``qmm_grouped`` → ``kernels/moe_gmm.py``):
           3 launches + 1 amax reduction per MoE block.
  vmapped  legacy ``jax.vmap`` over per-expert ``qlinear``: 3·E
           launches + E reductions — the A/B benchmarking fallback,
           and the path for non-moss quant modes and decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat.jaxapi import shard_map
from repro.core.formats import QuantConfig
from repro.core.linear import QT, qlinear, qlinear_grouped
from repro.core.runtime_flags import moe_expert_path
from repro.distributed.sharding import _active_mesh
from .layers import PDef


def moe_defs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    defs = {
        "router": PDef((d, e), (None, None)),          # f32, not quantized
        "w_up": PDef((e, d, f), ("experts", "fsdp", "mlp"), quantized=True),
        "w_gate": PDef((e, d, f), ("experts", "fsdp", "mlp"), quantized=True),
        "w_down": PDef((e, f, d), ("experts", "mlp", "fsdp"), quantized=True),
    }
    return defs


def _expert_ffn(cfg, w_up: QT, w_gate: QT, w_down: QT, x, qcfg):
    """One expert's gated FFN on its (C, d) token buffer."""
    up = qlinear(x, w_up, qcfg)
    gate = qlinear(x, w_gate, qcfg)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return qlinear(h, w_down, qcfg)


def _experts_vmapped(cfg, p, xs, qcfg):
    """xs: (E_local, C, d) -> (E_local, C, d); per-expert quant scales."""
    from repro.core.actscale import REC

    def one(w_up, w_gate, w_down, x):
        return _expert_ffn(cfg, w_up, w_gate, w_down, x, qcfg)

    if REC.recording:
        # calibration: python-loop the experts so each records its own
        # concrete activation amax under its (layer, expert) index.
        # QT fields are sliced by hand — the tag string in ``a`` is not
        # indexable, and vmap can't batch over a str leaf.
        def sl(wt, i):
            return QT(wt.w[i], None if wt.s is None else wt.s[i], wt.a)

        ys = []
        for i in range(xs.shape[0]):
            with REC.sub_index(i):
                ys.append(one(sl(p["w_up"], i), sl(p["w_gate"], i),
                              sl(p["w_down"], i), xs[i]))
        return jnp.stack(ys)
    return jax.vmap(one)(p["w_up"], p["w_gate"], p["w_down"], xs)


def _experts_grouped(cfg, p, xs, sizes, qcfg):
    """All expert FFNs through the grouped ragged kernel: xs (E, C, d)
    flattened to the sorted token buffer, 3 grouped GEMM launches + 1
    level-1 amax per GEMM instead of 3·E launches + E reductions.

    ``sizes`` is the ragged per-expert valid-row count from dispatch;
    None (the post-all_to_all EP case, where the counts live on the
    source shards) means every capacity slot is treated as full —
    dense-equivalent compute, still one launch per GEMM."""
    e, c, d = xs.shape
    if sizes is None:
        sizes = jnp.full((e,), c, jnp.int32)
    flat = xs.reshape(e * c, d)
    up = qlinear_grouped(flat, p["w_up"], sizes, c, qcfg)
    gate = qlinear_grouped(flat, p["w_gate"], sizes, c, qcfg)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(flat.dtype) * up
    y = qlinear_grouped(h, p["w_down"], sizes, c, qcfg)
    return y.reshape(e, c, d)


def _expert_runner(cfg, p, qcfg):
    """Selects the expert-GEMM path; returns fn(xs, sizes) -> ys.

    moss and bf16 route through the grouped kernel (bf16 grouped is
    bitwise identical to vmapped — same dots over the same rows); the
    per-tensor/per-group baselines keep the vmapped experts."""
    if qcfg.mode in ("moss", "bf16") and moe_expert_path() == "grouped":
        return lambda xs, sizes: _experts_grouped(cfg, p, xs, sizes, qcfg)
    return lambda xs, sizes: _experts_vmapped(cfg, p, xs, qcfg)


def router_probs(cfg, p, x_flat):
    """f32 router; returns (probs, aux metrics)."""
    w = p["router"]
    w = w.w if isinstance(w, QT) else w
    logits = x_flat.astype(jnp.float32) @ w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    return logits, probs


def load_balance_loss(probs, ids, n_experts: int, top_k: int):
    """Switch-style aux loss: E · Σ_e f_e · P_e."""
    one_hot = jax.nn.one_hot(ids, n_experts, dtype=jnp.float32)  # (T,k,E)
    f = one_hot.sum(axis=(0, 1)) / (ids.shape[0] * top_k)
    pmean = probs.mean(axis=0)
    return n_experts * jnp.sum(f * pmean)


def _dispatch_combine_local(cfg, x_loc, ids_loc, w_loc, expert_fn,
                            capacity: int, model_axis: str | None):
    """Per-device dispatch -> (all_to_all) -> experts -> (all_to_all) ->
    combine.  Runs inside shard_map (or standalone without a mesh)."""
    t_loc, d = x_loc.shape
    k = ids_loc.shape[-1]
    e = cfg.n_experts

    flat_ids = ids_loc.reshape(-1)                       # (T·k,)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    # position within expert group
    group_start = jnp.searchsorted(sorted_ids, jnp.arange(e))
    group_end = jnp.searchsorted(sorted_ids, jnp.arange(e), side="right")
    # ragged per-expert valid-row counts — the grouped kernel's group
    # sizes (capacity truncation applied, zero-size experts allowed)
    sizes = jnp.minimum(group_end - group_start,
                        capacity).astype(jnp.int32)
    pos = jnp.arange(t_loc * k) - group_start[sorted_ids]
    token_of = order // k
    keep = pos < capacity
    # scatter tokens into (E, C, d); dropped tokens overflow to a trash row
    buf = jnp.zeros((e * capacity + 1, d), x_loc.dtype)
    dest = jnp.where(keep, sorted_ids * capacity + pos, e * capacity)
    buf = buf.at[dest].set(x_loc[token_of])
    xs = buf[:-1].reshape(e, capacity, d)

    if model_axis is not None:
        xs = jax.lax.all_to_all(xs, model_axis, split_axis=0,
                                concat_axis=1, tiled=True)
        ys = expert_fn(xs, None)   # counts live on the source shards
        ys = jax.lax.all_to_all(ys, model_axis, split_axis=1,
                                concat_axis=0, tiled=True)
    else:
        ys = expert_fn(xs, sizes)                        # (E, C, d)

    ybuf = jnp.concatenate(
        [ys.reshape(e * capacity, d),
         jnp.zeros((1, d), ys.dtype)], axis=0)
    gathered = ybuf[dest]                                # (T·k, d) sorted
    # unsort back to (T, k, d)
    unsort = jnp.argsort(order, stable=True)
    per_slot = gathered[unsort].reshape(t_loc, k, d)
    y = jnp.einsum("tkd,tk->td", per_slot.astype(jnp.float32),
                   w_loc.astype(jnp.float32))
    return y.astype(x_loc.dtype)


def _capacity(cfg, t_local: int) -> int:
    c = int(t_local * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return -(-c // 8) * 8                                # round up to 8


def moe_block(cfg, p, x, qcfg: QuantConfig, mode: str = "train"):
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    logits, probs = router_probs(cfg, p, x_flat)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)     # (T,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    aux = load_balance_loss(probs, top_ids, cfg.n_experts, cfg.top_k)

    from repro.core.actscale import REC

    mesh = _active_mesh()
    use_ep = (mesh is not None and mode not in ("decode", "verify")
              and "model" in mesh.axis_names)
    # calibration (REC.recording) forces the dense every-expert path:
    # it is what decode runs, and sort-based dispatch would hand some
    # experts empty/truncated buffers — near-zero amaxes that would
    # catastrophically clip those experts at decode time.  The verify
    # step routes exactly like decode: per-token routing is
    # batch-composition-independent on the dense path, which the
    # token-for-token speculative exactness contract relies on.
    if mode in ("decode", "verify") or REC.recording or (
            not use_ep and cfg.moe_decode_dense and t <= 4096):
        y = _dense_moe(cfg, p, x_flat, probs, top_w, top_ids, qcfg)
        return y.reshape(b, s, d), aux

    if use_ep:
        token_axes = tuple(a for a in ("pod", "data", "model")
                           if a in mesh.axis_names)
        n_tok_shards = 1
        for a in token_axes:
            n_tok_shards *= mesh.shape[a]
        m = mesh.shape["model"]
        t_loc = t // n_tok_shards
        cap = _capacity(cfg, t_loc)

        def body(x_loc, ids_loc, w_loc, w_up, w_gate, w_down):
            # FSDP all-gather of expert weights over the data axis
            if "data" in mesh.axis_names:
                w_up = jax.lax.all_gather(w_up.w, "data", axis=1, tiled=True), w_up.s
                w_gate = jax.lax.all_gather(w_gate.w, "data", axis=1, tiled=True), w_gate.s
                w_down = jax.lax.all_gather(w_down.w, "data", axis=2, tiled=True), w_down.s
                w_up, w_gate, w_down = (QT(*w_up), QT(*w_gate), QT(*w_down))
            pl = {"w_up": w_up, "w_gate": w_gate, "w_down": w_down}
            fn = _expert_runner(cfg, pl, qcfg)
            return _dispatch_combine_local(cfg, x_loc, ids_loc, w_loc, fn,
                                           cap, "model")

        tok_spec = P(token_axes, None)
        wspec_up = P("model", "data" if "data" in mesh.axis_names else None,
                     None)
        wspec_down = P("model", None,
                       "data" if "data" in mesh.axis_names else None)
        sspec = P("model")
        y = shard_map(
            body, mesh=mesh,
            in_specs=(tok_spec, P(token_axes), tok_spec,
                      QT(wspec_up, sspec), QT(wspec_up, sspec),
                      QT(wspec_down, sspec)),
            out_specs=tok_spec,
            check_vma=False,
        )(x_flat, top_ids, top_w, p["w_up"], p["w_gate"], p["w_down"])
        return y.reshape(b, s, d), aux

    # single-device fallback (smoke tests)
    cap = _capacity(cfg, t)
    fn = _expert_runner(cfg, p, qcfg)
    y = _dispatch_combine_local(cfg, x_flat, top_ids, top_w, fn, cap, None)
    return y.reshape(b, s, d), aux


def _dense_moe(cfg, p, x_flat, probs, top_w, top_ids, qcfg):
    """Masked dense-experts combine for small T (decode)."""
    t, d = x_flat.shape
    combine = jnp.zeros((t, cfg.n_experts), jnp.float32).at[
        jnp.arange(t)[:, None], top_ids].set(top_w)
    ys = _experts_vmapped(cfg, p, jnp.broadcast_to(x_flat, (cfg.n_experts, t, d)),
                          qcfg)                           # (E,T,d)
    y = jnp.einsum("etd,te->td", ys.astype(jnp.float32), combine)
    return y.astype(x_flat.dtype)
