"""RecurrentGemma (Griffin) recurrent block: temporal conv + RG-LRU.

The RG-LRU recurrence is elementwise —
    r_t = σ(W_a u_t + b_a)          (recurrence gate)
    i_t = σ(W_i u_t + b_i)          (input gate)
    log a_t = -c · softplus(Λ) · r_t            (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)
— a linear scan, computed chunk-parallel: within-chunk associative scan,
lax.scan carrying h across chunks (bounded memory for prefill_32k /
long_500k).  Gates and projections are MOSS-quantized GEMMs; the
recurrence itself is elementwise f32 (DESIGN.md §6: not a GEMM, outside
the paper's quantization scope).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import QuantConfig
from repro.core.linear import QT, qlinear
from repro.distributed.sharding import shard
from .layers import PDef

_C = 8.0
_CHUNK = 256


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, lru)  f32
    conv: jax.Array       # (B, W-1, lru) last conv inputs
    idx: jax.Array


def rglru_defs(cfg):
    d, lru, w = cfg.d_model, cfg.lru_width, cfg.conv_width
    return {
        "w_x": PDef((d, lru), ("fsdp", "lru"), quantized=True),
        "w_gate_branch": PDef((d, lru), ("fsdp", "lru"), quantized=True),
        "w_out": PDef((lru, d), ("lru", "fsdp"), quantized=True),
        "conv_w": PDef((w, lru), ("conv", "lru"), "small"),
        "conv_b": PDef((lru,), ("lru",), "zeros"),
        "w_a": PDef((lru, lru), ("fsdp", "lru"), quantized=True),
        "b_a": PDef((lru,), ("lru",), "zeros"),
        "w_i": PDef((lru, lru), ("fsdp", "lru"), quantized=True),
        "b_i": PDef((lru,), ("lru",), "zeros"),
        "lambda_p": PDef((lru,), ("lru",), "ones"),
    }


def init_rglru_state(cfg, batch: int) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, cfg.lru_width), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width),
                       jnp.bfloat16),
        idx=jnp.zeros((), jnp.int32))


def cache_logical(cfg) -> RGLRUState:
    return RGLRUState(h=("batch", "lru"), conv=("batch", None, "lru"),
                      idx=())


def _causal_conv(p, u, prev):
    """Depthwise causal conv, width W.  prev: (B, W-1, lru) history."""
    w = p["conv_w"].w if isinstance(p["conv_w"], QT) else p["conv_w"]
    b = p["conv_b"].w if isinstance(p["conv_b"], QT) else p["conv_b"]
    width = w.shape[0]
    full = jnp.concatenate([prev.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u, shape=u.shape).astype(jnp.float32)
    s = u.shape[1]
    for i in range(width):
        sl = full[:, width - 1 - i: width - 1 - i + s]
        out = out + sl.astype(jnp.float32) * w[width - 1 - i].astype(jnp.float32)
    new_prev = full[:, -(width - 1):]
    return (out + b.astype(jnp.float32)).astype(u.dtype), new_prev


def _lru_scan(a, b, h0):
    """h_t = a_t h_{t-1} + b_t over axis 1, h0: (B, lru).  Chunked:
    within-chunk associative scan + per-chunk carry."""
    B, S, L = a.shape
    chunk = min(_CHUNK, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    ac = a.reshape(B, n, chunk, L).transpose(1, 0, 2, 3)
    bc = b.reshape(B, n, chunk, L).transpose(1, 0, 2, 3)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    def chunk_step(h, xs):
        a_i, b_i = xs                        # (B, chunk, L)
        cum_a, cum_b = jax.lax.associative_scan(combine, (a_i, b_i), axis=1)
        h_t = cum_b + cum_a * h[:, None, :]
        return h_t[:, -1, :], h_t

    h_last, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, n * chunk, L)[:, :S]
    return hs, h_last


def rglru_block(cfg, p, x, qcfg: QuantConfig,
                state: RGLRUState | None = None, mode: str = "train"):
    """x: (B,S,d) -> (y, new_state)."""
    b, s, _ = x.shape
    gate = qlinear(x, p["w_gate_branch"], qcfg)
    gate = jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    u = qlinear(x, p["w_x"], qcfg)
    u = shard(u, "batch", None, "lru")

    prev = (state.conv if state is not None
            else jnp.zeros((b, cfg.conv_width - 1, cfg.lru_width), x.dtype))
    u, conv_state = _causal_conv(p, u, prev)

    r = jax.nn.sigmoid(qlinear(u, p["w_a"], qcfg).astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(qlinear(u, p["w_i"], qcfg).astype(jnp.float32)
                       + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda_p"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i * u.astype(jnp.float32))

    h0 = (state.h if state is not None
          else jnp.zeros((b, cfg.lru_width), jnp.float32))
    if mode == "decode" and s == 1:
        h = a[:, 0] * h0 + gated_in[:, 0]
        hs = h[:, None, :]
        h_last = h
    else:
        hs, h_last = _lru_scan(a, gated_in, h0)

    y = (hs.astype(x.dtype) * gate)
    y = qlinear(y, p["w_out"], qcfg)
    new_state = RGLRUState(
        h=h_last, conv=conv_state.astype(jnp.bfloat16),
        idx=(state.idx if state is not None else jnp.zeros((), jnp.int32)) + s)
    return shard(y, "batch", "seq", "embed"), new_state
