"""RWKV-6 (Finch): attention-free time-mix with data-dependent decay.

Recurrence per head (head dim 64):
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    y_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)
computed chunk-parallel (FLA-style): within a chunk the pairwise decay
factorizes as (r_i e^{C_{i-1}}) · (k_j e^{-C_j}) with C the inclusive
per-channel cumulative log-decay, so intra-chunk work is two MXU-shaped
matmuls; the inter-chunk state is carried by lax.scan.  log-decay is
clamped to [-5, -1e-4] (chunk 16) so the factored exponentials stay in
f32 range — the same stability trick production linear-attention kernels
use.

Projections (r/k/v/g/o, channel-mix) are MOSS-quantized GEMMs; the WKV
state math is elementwise/outer-product f32 (DESIGN.md §6).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import QuantConfig
from repro.core.linear import QT, qlinear
from repro.distributed.sharding import shard
from .layers import PDef

_CHUNK = 16
_LW_MIN, _LW_MAX = -5.0, -1e-4


class RWKVState(NamedTuple):
    x_tm: jax.Array     # (B, d)  last input of time-mix
    x_cm: jax.Array     # (B, d)  last input of channel-mix
    S: jax.Array        # (B, H, dk, dv) wkv state, f32
    idx: jax.Array


_MIX = ("r", "k", "v", "w", "g")


def timemix_defs(cfg):
    d = cfg.d_model
    rank = cfg.ddlerp_rank
    dr = cfg.decay_rank
    defs = {
        "mu_base": PDef((d,), (None,), "small"),
        "mu": PDef((len(_MIX), d), (None, None), "small"),
        "ddlerp_w1": PDef((d, len(_MIX) * rank), ("fsdp", None),
                          quantized=True),
        "ddlerp_w2": PDef((len(_MIX), rank, d), (None, None, "fsdp"),
                          "small"),
        "w_r": PDef((d, d), ("fsdp", "heads"), quantized=True),
        "w_k": PDef((d, d), ("fsdp", "heads"), quantized=True),
        "w_v": PDef((d, d), ("fsdp", "heads"), quantized=True),
        "w_g": PDef((d, d), ("fsdp", "heads"), quantized=True),
        "w_o": PDef((d, d), ("heads", "fsdp"), quantized=True),
        "decay_base": PDef((d,), (None,), "small"),
        "decay_w1": PDef((d, dr), ("fsdp", None), quantized=True),
        "decay_w2": PDef((dr, d), (None, "fsdp"), "small"),
        "bonus_u": PDef((d,), (None,), "small"),
        "ln_scale": PDef((d,), (None,), "ones"),
        "ln_bias": PDef((d,), (None,), "zeros"),
    }
    return defs


def chanmix_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": PDef((d,), (None,), "small"),
        "mu_r": PDef((d,), (None,), "small"),
        "w_k": PDef((d, f), ("fsdp", "mlp"), quantized=True),
        "w_v": PDef((f, d), ("mlp", "fsdp"), quantized=True),
        "w_r": PDef((d, d), ("fsdp", "fsdp"), quantized=True),
    }


def init_rwkv_state(cfg, batch: int) -> RWKVState:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    return RWKVState(
        x_tm=jnp.zeros((batch, d), jnp.bfloat16),
        x_cm=jnp.zeros((batch, d), jnp.bfloat16),
        S=jnp.zeros((batch, h, dh, dh), jnp.float32),
        idx=jnp.zeros((), jnp.int32))


def cache_logical(cfg) -> RWKVState:
    return RWKVState(x_tm=("batch", None), x_cm=("batch", None),
                     S=("batch", "heads", None, None), idx=())


def _token_shift(x, x_prev):
    """shift right along seq: position t sees x_{t-1}; x_prev fills t=0."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)


def _raw(p):
    return p.w if isinstance(p, QT) else p


def _wkv_chunked(r, k, v, lw, u, S0):
    """r,k,v: (B,T,H,dh); lw: (B,T,H,dh) log-decay; u: (H,dh);
    S0: (B,H,dh,dh).  Returns (y (B,T,H,dh), S_last)."""
    b, t, h, dh = r.shape
    chunk = min(_CHUNK, t)
    n = -(-t // chunk)
    pad = n * chunk - t
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def resh(x):
        return x.reshape(b, n, chunk, h, dh).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(resh, (r, k, v, lw))   # (n, B, H, L, dh)

    def chunk_step(S, xs):
        ri, ki, vi, lwi = (x.astype(jnp.float32) for x in xs)
        C = jnp.cumsum(lwi, axis=-2)                       # inclusive
        C_prev = C - lwi                                    # C_{i-1}-style
        r_dec = ri * jnp.exp(C_prev)                        # (B,H,L,dh)
        k_dec = ki * jnp.exp(-C)
        # inter-chunk: y_i += (r_i e^{C_{i-1}}) S_prev
        y_inter = jnp.einsum("bhld,bhdv->bhlv", r_dec, S)
        # intra-chunk: scores[i,j] = Σ_d r_dec[i,d] k_dec[j,d] (j < i)
        scores = jnp.einsum("bhld,bhmd->bhlm", r_dec, k_dec)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhlm,bhmv->bhlv", scores, vi)
        # current-token bonus: (r_i ⊙ u ⊙ k_i) v_i
        bonus = jnp.einsum("bhld,bhld->bhl", ri * u[None, :, None, :], ki)
        y = y_inter + y_intra + bonus[..., None] * vi
        # state update: S' = diag(e^{C_L}) S + Σ_j e^{C_L - C_j} k_j v_j
        decay_all = jnp.exp(C[..., -1:, :])                # (B,H,1,dh)
        k_fold = ki * jnp.exp(C[..., -1:, :] - C)
        S_new = (S * decay_all.squeeze(-2)[..., None]
                 + jnp.einsum("bhld,bhlv->bhdv", k_fold, vi))
        return S_new, y

    S_last, ys = jax.lax.scan(chunk_step, S0.astype(jnp.float32),
                              (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, n * chunk, h, dh)[:, :t]
    return y, S_last


def _wkv_step(r, k, v, lw, u, S0):
    """Single-token recurrence (decode).  r,k,v,lw: (B,1,H,dh)."""
    ri, ki, vi, lwi = (x[:, 0].astype(jnp.float32) for x in (r, k, v, lw))
    y = (jnp.einsum("bhd,bhdv->bhv", ri, S0)
         + jnp.einsum("bhd,bhd->bh", ri * u[None], ki)[..., None] * vi)
    S_new = S0 * jnp.exp(lwi)[..., None] \
        + jnp.einsum("bhd,bhv->bhdv", ki, vi)
    return y[:, None], S_new


def _group_norm(y, scale, bias, eps=64e-5):
    """Per-head layernorm over dh (rwkv 'ln_x')."""
    mu = y.mean(axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    b_, t, h, dh = y.shape
    return (yn.reshape(b_, t, -1) * scale + bias).reshape(b_, t, h, dh)


def time_mix(cfg, p, x, qcfg: QuantConfig, state: RWKVState, mode: str):
    b, s, d = x.shape
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    x_prev = state.x_tm
    xs = _token_shift(x, x_prev)
    xx = (xs - x).astype(jnp.float32)

    # data-dependent token-shift (ddlerp)
    xxx = x.astype(jnp.float32) + xx * _raw(p["mu_base"])
    lora = jnp.tanh(qlinear(xxx.astype(x.dtype), p["ddlerp_w1"], qcfg)
                    .astype(jnp.float32))
    lora = lora.reshape(b, s, len(_MIX), cfg.ddlerp_rank)
    offs = jnp.einsum("bsmr,mrd->bsmd", lora, _raw(p["ddlerp_w2"])
                      .astype(jnp.float32))
    mixed = {}
    for i, name in enumerate(_MIX):
        m = _raw(p["mu"])[i] + offs[:, :, i]
        mixed[name] = (x.astype(jnp.float32) + xx * m).astype(x.dtype)

    r = qlinear(mixed["r"], p["w_r"], qcfg).reshape(b, s, h, dh)
    k = qlinear(mixed["k"], p["w_k"], qcfg).reshape(b, s, h, dh)
    v = qlinear(mixed["v"], p["w_v"], qcfg).reshape(b, s, h, dh)
    g = qlinear(mixed["g"], p["w_g"], qcfg)

    dd = jnp.tanh(qlinear(mixed["w"], p["decay_w1"], qcfg)
                  .astype(jnp.float32))
    dd = dd @ _raw(p["decay_w2"]).astype(jnp.float32)
    lw = -jnp.exp(_raw(p["decay_base"]).astype(jnp.float32) + dd)
    lw = jnp.clip(lw, _LW_MIN, _LW_MAX).reshape(b, s, h, dh)
    u = _raw(p["bonus_u"]).astype(jnp.float32).reshape(h, dh)

    if mode == "decode" and s == 1:
        y, S_new = _wkv_step(r, k, v, lw, u, state.S)
    else:
        y, S_new = _wkv_chunked(r, k, v, lw, u, state.S)

    y = _group_norm(y, _raw(p["ln_scale"]).astype(jnp.float32),
                    _raw(p["ln_bias"]).astype(jnp.float32))
    y = (y.reshape(b, s, d)
         * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = qlinear(y, p["w_o"], qcfg)
    new_state = state._replace(x_tm=x[:, -1].astype(jnp.bfloat16), S=S_new)
    return shard(out, "batch", "seq", "embed"), new_state


def channel_mix(cfg, p, x, qcfg: QuantConfig, state: RWKVState, mode: str):
    xs = _token_shift(x, state.x_cm)
    xx = (xs - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32) + xx * _raw(p["mu_k"])).astype(x.dtype)
    xr = (x.astype(jnp.float32) + xx * _raw(p["mu_r"])).astype(x.dtype)
    kk = qlinear(xk, p["w_k"], qcfg)
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    kk = shard(kk, "batch", None, "mlp")
    vv = qlinear(kk, p["w_v"], qcfg)
    rr = jax.nn.sigmoid(qlinear(xr, p["w_r"], qcfg).astype(jnp.float32))
    out = (rr * vv.astype(jnp.float32)).astype(x.dtype)
    new_state = state._replace(x_cm=x[:, -1].astype(jnp.bfloat16))
    return shard(out, "batch", "seq", "embed"), new_state
