"""Model assembly: family-specific blocks composed into segments, with
scan-over-layers (+ remat) so compile time and HLO size are
depth-independent.

A model = embed → [segments] → final norm → LM head.  Each segment is a
repeated block unit: params are stacked (n, ...) and applied with
lax.scan; per-unit KV caches / recurrent states are stacked the same
way and threaded through the scan.  Heterogeneous stacks (deepseek's
leading dense layer, recurrentgemma's trailing recurrent pair) are
separate segments.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import QuantConfig
from repro.distributed.sharding import shard
from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv6 as rwkv_mod
from .layers import (
    apply_ffn,
    apply_norm,
    embed_defs,
    embed_tokens,
    ffn_defs,
    lm_head,
    norm_defs,
    sinusoidal_embedding,
    stack_defs,
)


class Segment(NamedTuple):
    name: str
    n: int                       # repeats
    defs: dict                   # one unit's param defs (unstacked)
    apply: Callable              # (cfg,qcfg,p,x,pos,cache,mode)->(x,cache,aux)
    init_cache: Callable | None  # (cfg,batch,max_len)->one unit's cache
    cache_logical: Callable | None = None  # cfg -> logical axes pytree


# ---------------------------------------------------------------------------
# Block bodies
# ---------------------------------------------------------------------------


def _dense_unit(cfg, d_ff=None):
    return {
        "ln1": norm_defs(cfg, cfg.d_model),
        "attn": attn_mod.attn_defs(cfg),
        "ln2": norm_defs(cfg, cfg.d_model),
        "ffn": ffn_defs(cfg, d_ff),
    }


def _dense_apply(cfg, qcfg, p, x, pos, cache, mode):
    h, cache = attn_mod.attention(cfg, p["attn"],
                                  apply_norm(cfg, p["ln1"], x), pos, qcfg,
                                  cache, mode)
    x = x + h
    h = apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x), qcfg)
    return x + h, cache, jnp.zeros((), jnp.float32)


def _moe_unit(cfg, use_mla: bool):
    unit = {
        "ln1": norm_defs(cfg, cfg.d_model),
        "attn": mla_mod.mla_defs(cfg) if use_mla else attn_mod.attn_defs(cfg),
        "ln2": norm_defs(cfg, cfg.d_model),
        "moe": moe_mod.moe_defs(cfg),
    }
    if cfg.n_shared > 0:
        unit["shared"] = ffn_defs(cfg, cfg.n_shared * cfg.d_ff)
    return unit


def _moe_apply_factory(use_mla: bool):
    def apply(cfg, qcfg, p, x, pos, cache, mode):
        att = mla_mod.mla_attention if use_mla else attn_mod.attention
        h, cache = att(cfg, p["attn"], apply_norm(cfg, p["ln1"], x), pos,
                       qcfg, cache, mode)
        x = x + h
        hn = apply_norm(cfg, p["ln2"], x)
        h, aux = moe_mod.moe_block(cfg, p["moe"], hn, qcfg, mode)
        if cfg.n_shared > 0:
            h = h + apply_ffn(cfg, p["shared"], hn, qcfg)
        return x + h, cache, aux
    return apply


def _rec_unit(cfg):
    return {
        "ln1": norm_defs(cfg, cfg.d_model),
        "rec": rglru_mod.rglru_defs(cfg),
        "ln2": norm_defs(cfg, cfg.d_model),
        "ffn": ffn_defs(cfg),
    }


def _rec_apply(cfg, qcfg, p, x, pos, cache, mode):
    h, cache = rglru_mod.rglru_block(cfg, p["rec"],
                                     apply_norm(cfg, p["ln1"], x), qcfg,
                                     cache, mode)
    x = x + h
    h = apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x), qcfg)
    return x + h, cache, jnp.zeros((), jnp.float32)


def _griffin_unit(cfg):
    """RecurrentGemma repeating unit: (rec, rec, local-attn), each with
    its own FFN sub-block (1:2 attention:recurrence ratio)."""
    return {
        "rec0": _rec_unit(cfg),
        "rec1": _rec_unit(cfg),
        "attn0": _dense_unit(cfg),
    }


def _griffin_apply(cfg, qcfg, p, x, pos, cache, mode):
    cache = cache if cache is not None else (None, None, None)
    x, c0, _ = _rec_apply(cfg, qcfg, p["rec0"], x, pos, cache[0], mode)
    x, c1, _ = _rec_apply(cfg, qcfg, p["rec1"], x, pos, cache[1], mode)
    x, c2, _ = _dense_apply(cfg, qcfg, p["attn0"], x, pos, cache[2], mode)
    return x, (c0, c1, c2), jnp.zeros((), jnp.float32)


def _rwkv_unit(cfg):
    return {
        "ln1": norm_defs(cfg, cfg.d_model),
        "tm": rwkv_mod.timemix_defs(cfg),
        "ln2": norm_defs(cfg, cfg.d_model),
        "cm": rwkv_mod.chanmix_defs(cfg),
    }


def _rwkv_apply(cfg, qcfg, p, x, pos, cache, mode):
    st = cache if cache is not None else rwkv_mod.init_rwkv_state(
        cfg, x.shape[0])
    h, st = rwkv_mod.time_mix(cfg, p["tm"],
                              apply_norm(cfg, p["ln1"], x), qcfg, st, mode)
    x = x + h
    h, st = rwkv_mod.channel_mix(cfg, p["cm"],
                                 apply_norm(cfg, p["ln2"], x), qcfg, st, mode)
    return x + h, st, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Segments per family
# ---------------------------------------------------------------------------


def build_segments(cfg) -> list[Segment]:
    if cfg.family in ("dense", "audio", "vlm"):
        return [Segment("blocks", cfg.n_layers, _dense_unit(cfg),
                        _dense_apply, attn_mod.init_cache,
                        attn_mod.cache_logical)]
    if cfg.family == "moe":
        return [Segment("blocks", cfg.n_layers, _moe_unit(cfg, False),
                        _moe_apply_factory(False), attn_mod.init_cache,
                        attn_mod.cache_logical)]
    if cfg.family == "mla_moe":
        segs = []
        if cfg.first_dense:
            dense_cfg = {
                "ln1": norm_defs(cfg, cfg.d_model),
                "attn": mla_mod.mla_defs(cfg),
                "ln2": norm_defs(cfg, cfg.d_model),
                "ffn": ffn_defs(cfg, cfg.dense_ff or cfg.d_ff),
            }

            def dense_mla_apply(cfg_, qcfg, p, x, pos, cache, mode):
                h, cache = mla_mod.mla_attention(
                    cfg_, p["attn"], apply_norm(cfg_, p["ln1"], x), pos,
                    qcfg, cache, mode)
                x = x + h
                h = apply_ffn(cfg_, p["ffn"], apply_norm(cfg_, p["ln2"], x),
                              qcfg)
                return x + h, cache, jnp.zeros((), jnp.float32)

            segs.append(Segment("dense0", cfg.first_dense, dense_cfg,
                                dense_mla_apply, mla_mod.init_mla_cache,
                                mla_mod.cache_logical))
        segs.append(Segment("blocks", cfg.n_layers - cfg.first_dense,
                            _moe_unit(cfg, True), _moe_apply_factory(True),
                            mla_mod.init_mla_cache, mla_mod.cache_logical))
        return segs
    if cfg.family == "hybrid":
        n_units, rem = divmod(cfg.n_layers, 3)
        segs = [Segment("griffin", n_units, _griffin_unit(cfg),
                        _griffin_apply, _griffin_cache,
                        _griffin_cache_logical)]
        if rem:
            segs.append(Segment("tail_rec", rem, _rec_unit(cfg),
                                _rec_apply, _rec_cache,
                                rglru_mod.cache_logical))
        return segs
    if cfg.family == "ssm":
        return [Segment("blocks", cfg.n_layers, _rwkv_unit(cfg),
                        _rwkv_apply,
                        lambda c, b, m: rwkv_mod.init_rwkv_state(c, b),
                        rwkv_mod.cache_logical)]
    raise ValueError(cfg.family)


def _rec_cache(cfg, batch, max_len):
    return rglru_mod.init_rglru_state(cfg, batch)


def _griffin_cache(cfg, batch, max_len):
    return (rglru_mod.init_rglru_state(cfg, batch),
            rglru_mod.init_rglru_state(cfg, batch),
            attn_mod.init_cache(cfg, batch, max_len))


def _griffin_cache_logical(cfg):
    return (rglru_mod.cache_logical(cfg), rglru_mod.cache_logical(cfg),
            attn_mod.cache_logical(cfg))


def cache_logical_tree(cfg):
    """Logical sharding axes matching init_caches (stacked: leading
    'layers' axis on array leaves)."""
    out = {}
    for seg in build_segments(cfg):
        if seg.cache_logical is None:
            out[seg.name] = None
            continue
        one = seg.cache_logical(cfg)
        out[seg.name] = jax.tree.map(
            lambda ax: ("layers", *ax), one,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x))
    return out


# ---------------------------------------------------------------------------
# Whole-model defs / forward
# ---------------------------------------------------------------------------


def model_defs(cfg) -> dict:
    segs = build_segments(cfg)
    defs: dict[str, Any] = {
        "embed": embed_defs(cfg),
        "final_norm": norm_defs(cfg, cfg.d_model),
    }
    for seg in segs:
        defs[seg.name] = stack_defs(seg.defs, seg.n)
    return defs


def map_cache_nodes(tree, fn):
    """Apply ``fn`` to every cache/state NamedTuple (KVCache, MLACache,
    RGLRUState, RWKVState — anything with an ``idx`` field) inside a
    caches pytree, preserving the surrounding dict/tuple structure."""
    if hasattr(tree, "_replace") and hasattr(tree, "idx"):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: map_cache_nodes(v, fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(map_cache_nodes(v, fn) for v in tree)
    return tree


def iter_cache_nodes(tree):
    """Yield every cache/state NamedTuple (see ``map_cache_nodes``)."""
    if hasattr(tree, "_replace") and hasattr(tree, "idx"):
        yield tree
    elif isinstance(tree, dict):
        for v in tree.values():
            yield from iter_cache_nodes(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from iter_cache_nodes(v)


def init_caches(cfg, batch: int, max_len: int, per_slot: bool = False):
    """Stacked caches for every segment (decode/prefill).

    ``per_slot=True`` builds the serving-engine variant: every cache
    node's ``idx`` becomes a (batch,) vector so decode slots track
    independent depths (docs/continuous-batching.md).  The payload
    layout is identical — only the write-position/validity bookkeeping
    widens."""
    caches = {}
    for seg in build_segments(cfg):
        if seg.init_cache is None:
            caches[seg.name] = None
            continue
        one = seg.init_cache(cfg, batch, max_len)
        if per_slot:
            one = map_cache_nodes(
                one, lambda n: n._replace(
                    idx=jnp.zeros((batch,), jnp.int32)))
        caches[seg.name] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (seg.n, *x.shape)).copy()
            if hasattr(x, "shape") else x, one)
    return caches


def paged_decode_supported(cfg, max_len: int, page_size: int) -> bool:
    """True iff this (cfg, max_len) can decode from a floating page
    pool (docs/paged-attention.md): per-head KVCache families only
    (MLA's latent cache and the ssm/hybrid recurrent states have no
    page structure), no window/ring semantics (the pool append writes
    ``idx // T`` directly), and C a whole number of pages."""
    if cfg.family not in ("dense", "audio", "vlm", "moe"):
        return False
    c = attn_mod.cache_len(cfg, max_len)
    return c == max_len and c % page_size == 0


def chunk_prefill_supported(cfg, max_len: int) -> bool:
    """True iff chunked prefill can write prompt chunks at an offset
    into this family's cache (docs/continuous-batching.md): per-head
    KVCache families only, and no window/ring semantics — chunk
    positions map to absolute cache slots, never wrap."""
    if cfg.family not in ("dense", "audio", "vlm", "moe"):
        return False
    return attn_mod.cache_len(cfg, max_len) == max_len


def spec_verify_supported(cfg, max_len: int) -> bool:
    """True iff the speculative verify step can run against this
    (cfg, max_len) (docs/speculative-decoding.md): the same gate as
    chunked prefill — per-head KVCache families with an unwrapped
    (C == max_len) cache, since a k-token verify write lands at
    absolute positions and rejection truncates the length vector,
    neither of which has ring semantics."""
    return chunk_prefill_supported(cfg, max_len)


def init_paged_pools(cfg, max_len: int, num_pages: int,
                     page_size: int) -> dict:
    """Stacked floating-page pool caches for every segment — the
    serving engine's float-placement variant of ``init_caches``.
    Each array leaf gains the leading layers axis exactly like
    ``init_caches``; per-slot ``idx`` / ``block_table`` leaves start
    at batch 0 (the engine restamps them from host state every step).
    The pool carries ``num_pages + 1`` physical rows: the extra last
    row is the TRASH page — chunked-prefill padding positions and
    unassigned block-table entries point at it, so garbage scatters
    never land in another request's page (its bytes are never read;
    docs/continuous-batching.md).  Requires
    ``paged_decode_supported``."""
    assert paged_decode_supported(cfg, max_len, page_size)
    pps = attn_mod.cache_len(cfg, max_len) // page_size
    caches = {}
    for seg in build_segments(cfg):
        one = attn_mod.init_page_pool(cfg, num_pages + 1, pps, 0,
                                      page_size)
        caches[seg.name] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (seg.n, *x.shape)).copy()
            if hasattr(x, "shape") else x, one)
    return caches


def _qh_drain():
    # quant-health ys hook: None (the slot's historical value) unless a
    # collection window is open — see repro.obs.quant_health.
    from repro.obs.quant_health import QH
    return QH.drain_layer()


def _qh_stash(tree) -> None:
    if tree:
        from repro.obs.quant_health import QH
        QH.stash_stacked(tree)


def forward(cfg, qcfg: QuantConfig, params, batch: dict,
            caches=None, mode: str = "train"):
    """Returns (logits, new_caches, aux_loss).

    batch: {"tokens": (B,S) int32} or {"embeds": (B,S,d)}; decode mode
    additionally relies on caches' idx for positions.
    """
    if cfg.input_mode == "embeddings" and "embeds" in batch:
        x = batch["embeds"].astype(jnp.bfloat16)
        x = shard(x, "batch", "seq", "embed")
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed_tokens(cfg, params["embed"], tokens)

    if mode in ("decode", "verify") and caches is not None:
        pos0 = _first_idx(caches)
        if pos0.ndim:        # per-slot cache: (B,) depths -> (B, S)
            positions = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)
        else:
            positions = pos0 + jnp.arange(s, dtype=jnp.int32)
    else:
        positions = jnp.arange(s, dtype=jnp.int32)

    if cfg.pos_embedding == "sinusoidal":
        pe = sinusoidal_embedding(positions, cfg.d_model)
        x = x + (pe if positions.ndim > 1 else pe[None]).astype(x.dtype)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    for seg in build_segments(cfg):
        p_seg = params[seg.name]
        c_seg = caches.get(seg.name) if caches is not None else None

        if c_seg is None:
            # train: no cache threaded; params are the scan xs
            def body(carry, p_l, seg=seg):
                x_, aux_ = carry
                x_, _, aux_l = seg.apply(cfg, qcfg, p_l, x_, positions,
                                         None, mode)
                return (x_, aux_ + aux_l), None

            if cfg.remat and mode == "train":
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), p_seg,
                                             length=seg.n)
            new_caches[seg.name] = None
        else:
            # serving: the stacked cache rides in the CARRY (not xs/ys)
            # so the while loop aliases it in place — one copy of the
            # multi-GB KV cache instead of separate in/out stacks.
            # The ys slot carries the quant-health stats when a
            # collection window is open (stacked to (layers, ...) by
            # scan itself) and stays None — the jaxpr it always had —
            # otherwise (repro.obs.quant_health).
            def body(carry, p_l, seg=seg):
                x_, aux_, c_stack, li = carry
                c_l = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, li, 0, keepdims=False), c_stack)
                x_, c_new, aux_l = seg.apply(cfg, qcfg, p_l, x_,
                                             positions, c_l, mode)
                c_stack = jax.tree.map(
                    lambda c, u: jax.lax.dynamic_update_index_in_dim(
                        c, u.astype(c.dtype), li, 0), c_stack, c_new)
                return (x_, aux_ + aux_l, c_stack, li + 1), _qh_drain()

            (x, aux_total, c_seg, _), hs = jax.lax.scan(
                body, (x, aux_total, c_seg, jnp.zeros((), jnp.int32)),
                p_seg, length=seg.n)
            _qh_stash(hs)
            new_caches[seg.name] = c_seg

    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params["embed"], x, qcfg)
    return logits, (new_caches if caches is not None else None), aux_total


def _first_idx(caches):
    # every cache tracks the same absolute position(s); take any `idx`.
    # Stacked over layers: (L,) shared scalar -> (), (L, B) per-slot
    # vector -> (B,) — strip the layer dim and return the rest.
    for c in caches.values():
        if c is None:
            continue
        for node in iter_cache_nodes(c):
            if node.idx is not None:
                return node.idx[0]
    return jnp.zeros((), jnp.int32)


def ce_loss(cfg, logits, labels, mask=None):
    """Token cross-entropy in f32.

    The label pick is an iota-compare + masked sum (not take_along_axis)
    so a vocab-sharded logits tensor reduces locally + all-reduces a
    scalar instead of being gathered (GSPMD-friendly)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], shifted,
                               0.0), axis=-1)
    ll = picked - lse
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
