"""Fault-tolerant checkpointing: atomic commits, manifest with logical
sharding metadata, resume-from-latest, and mesh resharding on restore
(elastic restarts: save on 512 chips, restore on 256 — or on 1 CPU).

Layout:
  <dir>/step_000123.tmp/...   (written)
  <dir>/step_000123/          (atomic rename = commit)
      manifest.json           {step, tree paths, shapes, dtypes, specs}
      arrays.npz              leaf arrays (gathered)

The data pipeline is stateless-deterministic (step -> batch), so
restoring {params, opt_state, scale_states, step} fully resumes
training.  A SIGTERM handler lets the training loop checkpoint before
preemption (launch/train.py).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Gather + write + atomic rename.  Returns the committed path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": path, "key": key, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)           # atomic commit
    # prune older checkpoints (keep last 3)
    kept = sorted(d for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and not d.endswith(".tmp"))
    for d in kept[:-3]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template``; if ``shardings`` (a
    matching pytree of NamedSharding) is given, place each leaf sharded
    on the *current* mesh — this is the elastic resharding path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    by_path = {l["path"]: data[l["key"]] for l in manifest["leaves"]}

    flat_t = _flatten_with_paths(template)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_t))
    leaves = []
    for (p, tleaf), sh in zip(flat_t, shard_leaves):
        arr = by_path[p]
        want = np.dtype(jax.numpy.asarray(tleaf).dtype
                        if not hasattr(tleaf, "dtype") else tleaf.dtype)
        arr = arr.astype(want, copy=False)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jnp.asarray(arr))
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves), manifest["step"]
