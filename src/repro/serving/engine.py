"""Continuous-batching serving engine over the paged KV cache — the
layer between the model's prefill/decode step functions and the
``launch/serve.py`` CLI (docs/continuous-batching.md).

One engine ``step()``:

  1. retire finished requests: unreference their pages, then either
     refill the row in place from the queue (steady state) or
     swap-shrink it out of the decode batch (tail drain — finished
     slots never feed another decode step);
  2. admit queued requests while slots and pages allow — admission is
     ACTUAL free-pool accounting (outstanding private reservations vs
     allocatable pages, ``PageAllocator.can_admit``), not a
     worst-case contiguous-row count; page exhaustion = backpressure,
     the request stays queued;
  3. one batched decode over the resident rows — every row active,
     each at its own depth via the per-slot length vector that flows
     ``KVCache.idx (B,)`` -> per-slot RoPE positions -> per-slot
     writes -> the decode-attention kernel's ``n_valid`` scalar-
     prefetch vector.

Page placement (``REPRO_PAGED_PLACEMENT``, docs/paged-attention.md):
where the family supports it (per-head KV cache, no window, C a
whole number of pages) the cache is a ``FloatingPageCache`` — one
global page pool, per-slot block tables threaded into the decode
kernel as a scalar-prefetch operand.  Other families (MLA latent,
recurrent state, windowed rings) and the ``identity`` override keep
the PR5 per-slot contiguous rows.

Prefix caching (float placement only, ``REPRO_PREFIX_CACHE``): at
admission the head request's page-aligned prompt prefix is hashed
(``page_keys`` — chained, so key j covers tokens [0, (j+1)*T)) and
looked up; on a hit the request maps the shared physical pages
copy-on-write, SKIPS the prefill of those chunks entirely, and the
engine replays only the remaining prompt tokens through ordinary
batched decode steps (samples discarded until the last prompt token
is fed — its sample is the request's first output token and stamps
TTFT).  A cold request's full prompt pages are registered after its
prefill insert; a prefix-hit request's additional full pages register
when its replay completes.  Shared pages are never written in place:
``FloatingPageCache.prepare_decode`` copies-before-write
(refcount > 1 or hash-registered), bounded at ONE CoW per request
(only a fully-page-aligned full hit ever writes into a shared page).

Prefill runs one request at a time (B=1) into a fresh cache and the
result row is merged into the batch (identity) or scattered into
pool pages (float) — so a request's tokens are bitwise independent
of whichever other requests happen to be resident (the mixed-depth
parity contract, asserted in tests/test_paged_serving.py).  Prompts
are right-padded to a compile bucket (``prompt_bucket``) so prefill
compiles once per bucket, not once per prompt length; the true
length is what gets stamped into the merged row's ``idx``, so padded
garbage positions are never attended.

Weights are pre-quantized at build exactly like the legacy Server
(``PrequantParams``; ``REPRO_SERVE_PREQUANT=0`` falls back to cached
scales).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime_flags import (
    paged_placement,
    serve_prefix_cache,
    serve_prequant,
)
from repro.models.transformer import paged_decode_supported
from repro.train.steps import (
    make_decode_step,
    make_prefill_step,
    prequantize_params,
    serve_weight_scales,
)

from .paged_cache import (
    PAGE_SIZE,
    FloatingPageCache,
    PagedKVCache,
    PageExhausted,
    SlotCapacityExceeded,
    page_keys,
)
from .scheduler import Request, Scheduler

PROMPT_BUCKET = 16


def prepare_weights(cfg, params):
    """Build-time weight preparation shared by the engine and the
    legacy Server: pre-quantized fp8 payloads + scales by default,
    cached per-tensor scales under ``REPRO_SERVE_PREQUANT=0``.
    Returns (params_tree, scales, prequant_or_None)."""
    prequant = (prequantize_params(cfg, params)
                if serve_prequant() else None)
    if prequant is not None:
        return prequant.qweights, prequant.scales, prequant
    return params, serve_weight_scales(cfg, params), None


def greedy_sample(logits):
    """(B, 1, V) last-position logits -> (B,) next token ids."""
    return jnp.argmax(logits[:, -1], axis=-1)


@dataclasses.dataclass
class PrefixPlan:
    """Admission-time prefix-cache decision for one request.

    ``keys``        chained page hashes of every FULL prompt page
    ``pages``       physical pages hit (longest registered prefix run,
                    clamped to the prompt's full pages) — empty = cold
    ``replay_from`` first prompt position fed through decode instead
                    of prefill: ``min(n_shared*T, prompt_len - 1)``
                    (a FULL hit still replays the last prompt token,
                    whose sample is the first output)
    ``cow_slack``   1 when the replay write lands inside a shared page
                    (full page-aligned hit), else 0 — reserved so the
                    copy-on-write can always allocate"""
    keys: list
    pages: list
    replay_from: int
    cow_slack: int


class Engine:
    """Paged-KV continuous-batching engine (see module docstring)."""

    def __init__(self, cfg, params, num_slots: int, max_len: int, *,
                 page_size: int = PAGE_SIZE,
                 num_pages: int | None = None,
                 prompt_bucket: int = PROMPT_BUCKET,
                 eos_id: int | None = None,
                 prefix_cache: bool | None = None):
        if cfg.input_mode != "tokens":
            raise ValueError(
                f"serving engine drives token models; {cfg.name} has "
                f"input_mode={cfg.input_mode!r}")
        self.cfg = cfg
        self.max_len = max_len
        self.num_slots = num_slots
        # recurrent state (RWKV / RG-LRU) integrates every prefill
        # token — padded garbage would corrupt it (attention caches
        # just mask it), so those families prefill at exact length
        # (one compile per distinct prompt length)
        self.prompt_bucket = (1 if cfg.family in ("ssm", "hybrid")
                              else prompt_bucket)
        self.eos_id = eos_id
        self.params, self.scales, self.prequant = \
            prepare_weights(cfg, params)
        self.prefill = jax.jit(make_prefill_step(cfg, max_len,
                                                 scales=self.scales))
        self.decode = jax.jit(make_decode_step(cfg, scales=self.scales),
                              donate_argnums=(1,))
        self.float_pages = (paged_placement() == "float"
                            and paged_decode_supported(cfg, max_len,
                                                       page_size))
        if self.float_pages:
            self.kv = FloatingPageCache(cfg, max_len, num_slots,
                                        page_size=page_size,
                                        num_pages=num_pages)
        else:
            self.kv = PagedKVCache(cfg, max_len, num_slots,
                                   page_size=page_size,
                                   num_pages=num_pages)
        self.prefix_cache = (self.float_pages
                             and (serve_prefix_cache()
                                  if prefix_cache is None
                                  else prefix_cache))
        # prompt tokens still owed to decode-step replay per prefix-hit
        # request, and the page keys to register when replay completes
        self._replay: dict[int, deque] = {}
        self._replay_keys: dict[int, list] = {}
        self.prefill_calls = 0
        self.prefill_tokens_skipped = 0
        self.prefix_hits = 0
        self.pages_shared = 0
        self.sched = Scheduler()
        self.requests: dict[int, Request] = {}

    # -- admission -----------------------------------------------------
    def _total_tokens(self, req: Request) -> int:
        # worst-case resident K/V: prompt + every decode-step write
        # (the last generated token is sampled but never written)
        return req.prompt_len + req.max_new - 1

    def submit(self, requests: list[Request]) -> None:
        for req in requests:
            if req.eos_id is None:
                req.eos_id = self.eos_id
            total = self._total_tokens(req)
            if not self.kv.ring and total > self.kv.slot_tokens:
                raise SlotCapacityExceeded(
                    f"request {req.rid}: prompt {req.prompt_len} + "
                    f"max_new {req.max_new} needs {total} cache "
                    f"positions > slot capacity {self.kv.slot_tokens}")
            al = self.kv.allocator
            need = al.pages_needed(self.kv._resident(total))
            if need > al.num_pages:
                # can never be admitted: reject at submit instead of
                # letting head-of-line FIFO livelock the queue
                raise PageExhausted(
                    f"request {req.rid}: worst-case reservation of "
                    f"{need} pages exceeds the whole pool "
                    f"({al.num_pages} pages)")
            self.requests[req.rid] = req
        self.sched.submit(requests)

    def _bucket_len(self, n: int) -> int:
        c = self.kv.slot_tokens
        if n >= c:
            return n          # ring keep-last-C prefill path, exact
        return min(c, -(-n // self.prompt_bucket) * self.prompt_bucket)

    def _prefill_request(self, req: Request):
        """B=1 prefill of a (bucket-padded) prompt; returns the one-row
        caches.  Emits the request's first generated token (TTFT)."""
        n = req.prompt_len
        toks = np.zeros((1, self._bucket_len(n)), np.int32)
        toks[0, :n] = req.prompt
        logits, one = self.prefill(self.params, {"tokens":
                                                 jnp.asarray(toks)},
                                   jnp.int32(min(n, toks.shape[1]) - 1))
        self.prefill_calls += 1
        self.sched.on_token(req, int(greedy_sample(logits)[0]))
        return one

    def _prefix_plan(self, req: Request) -> PrefixPlan | None:
        """Look the request's page-aligned prompt prefix up in the
        hash map (None when prefix caching is off)."""
        if not self.prefix_cache:
            return None
        t = self.kv.page_size
        keys = page_keys(req.prompt, t)
        pages = self.kv.allocator.lookup(keys)
        n_shared = len(pages)
        if n_shared == 0:
            return PrefixPlan(keys=keys, pages=[], replay_from=0,
                              cow_slack=0)
        replay_from = min(n_shared * t, req.prompt_len - 1)
        cow_slack = 1 if n_shared * t >= req.prompt_len else 0
        return PrefixPlan(keys=keys, pages=pages,
                          replay_from=replay_from, cow_slack=cow_slack)

    def _admissible_head(self):
        """(head request, prefix plan) when the queue head fits under
        the pool's actual free-page accounting, else None."""
        head = self.sched.peek()
        if head is None:
            return None
        plan = self._prefix_plan(head)
        total = self._total_tokens(head)
        if plan is not None and plan.pages:
            ok = self.kv.can_admit(total, shared=plan.pages,
                                   cow_slack=plan.cow_slack)
            if not ok and self.kv.can_admit(total):
                # the hit needs MORE headroom than a cold admit (page
                # revival + CoW slack, e.g. a minimal pool): serve it
                # cold rather than livelock the FIFO head forever
                plan = PrefixPlan(keys=plan.keys, pages=[],
                                  replay_from=0, cow_slack=0)
                ok = True
        else:
            ok = self.kv.can_admit(total)
        return (head, plan) if ok else None   # else: stays queued

    def _admit(self, req: Request, plan: PrefixPlan | None,
               row: int | None = None) -> None:
        """Admit one popped request — prefix-hit (map shared pages,
        queue the prompt-tail replay, NO prefill) or cold (B=1
        prefill, insert, register prompt hashes)."""
        total = self._total_tokens(req)
        if plan is not None and plan.pages:
            self.kv.admit_shared(req.rid, plan.pages, plan.replay_from,
                                 total, plan.cow_slack, row=row)
            self._replay[req.rid] = deque(
                int(tok) for tok in req.prompt[plan.replay_from:])
            self._replay_keys[req.rid] = plan.keys
            self.prefix_hits += 1
            self.prefill_tokens_skipped += plan.replay_from
            self.pages_shared += len(plan.pages)
            req.prefix_pages = len(plan.pages)
            req.prefill_skipped = plan.replay_from
            return
        one = self._prefill_request(req)
        if row is None:
            self.kv.append(req.rid, one, req.prompt_len, total)
        else:
            self.kv.refill(row, req.rid, one, req.prompt_len, total)
        if plan is not None:
            self.kv.register_prompt(req.rid, plan.keys)

    # -- the engine step -----------------------------------------------
    def step(self) -> None:
        self._retire_and_refill()
        self._admit_new_rows()
        self._decode_once()

    def _retire_and_refill(self):
        row = 0
        while row < len(self.kv.rows):
            owner = self.kv.rows[row]
            if owner is not None and not self.requests[owner].done:
                row += 1
                continue
            if owner is not None:
                self.kv.release(row)
            head = self._admissible_head()
            if head is not None:
                req, plan = head
                self.sched.pop()
                self._admit(req, plan, row=row)
                # a cold refill may itself already be done (max_new ==
                # 1 or instant EOS): the loop re-checks this row
            else:
                self.kv.shrink(row)
                # the swapped-in last row is re-checked at this index

    def _admit_new_rows(self):
        while len(self.kv.rows) < self.num_slots:
            head = self._admissible_head()
            if head is None:
                break
            req, plan = head
            self.sched.pop()
            self._admit(req, plan)
            if self.requests[req.rid].done:       # instant finish
                self._retire_and_refill()

    def _decode_once(self):
        rows = self.kv.rows
        if not rows:
            return
        # feed: a replayed prompt token for prefix-hit rows still
        # catching up, else the row's last sampled token
        feed = np.zeros((len(rows), 1), np.int32)
        for i, rid in enumerate(rows):
            pending = self._replay.get(rid)
            if pending:
                feed[i, 0] = pending.popleft()
            else:
                feed[i, 0] = self.requests[rid].out[-1]
        if self.float_pages:
            # copy-on-write barrier + idx/block-table restamp: every
            # row's write-target page must be private BEFORE the
            # in-graph append
            self.kv.prepare_decode()
        logits, self.kv.caches = self.decode(
            self.params, self.kv.caches, jnp.asarray(feed))
        self.kv.advance()
        nxt = np.asarray(greedy_sample(logits))
        for i, rid in enumerate(list(rows)):
            if rid in self._replay:
                if self._replay[rid]:
                    continue      # mid-replay: the sample predicts a
                                  # prompt token we already have
                # the last prompt token was just fed: this sample is
                # the request's FIRST output token (stamps TTFT), and
                # the row's full prompt pages are now written —
                # publish their hashes
                del self._replay[rid]
                self.kv.register_prompt(
                    rid, self._replay_keys.pop(rid))
            self.sched.on_token(self.requests[rid], int(nxt[i]))

    # -- driver --------------------------------------------------------
    def run(self, requests: list[Request] | None = None, log=print):
        """Drain the queue; returns the requests that finished during
        THIS call (an engine instance can serve several runs — the jit
        caches on its step functions carry over)."""
        if requests:
            self.submit(requests)
        done_before = {rid for rid, r in self.requests.items() if r.done}
        toks_before = sum(len(r.out) for r in self.requests.values())
        t0 = time.monotonic()
        steps = 0
        while self.sched.queue or self.kv.rows:
            self.step()
            steps += 1
            if steps > 100_000:
                raise RuntimeError("serving loop did not converge")
        dt = time.monotonic() - t0
        done = [r for rid, r in self.requests.items()
                if r.done and rid not in done_before]
        toks = sum(len(r.out) for r in self.requests.values()) \
            - toks_before
        if log is not None:
            ttfts = [r.ttft for r in done if r.ttft is not None]
            tpots = [r.tpot for r in done if r.tpot is not None]
            mean = lambda v: float(np.mean(v)) if v else float("nan")
            log(f"served {len(done)} requests, {toks} tokens in "
                f"{dt:.2f}s ({toks / max(dt, 1e-9):,.1f} tok/s, "
                f"{steps} engine steps, mean TTFT "
                f"{1e3 * mean(ttfts):.1f} ms, mean TPOT "
                f"{1e3 * mean(tpots):.1f} ms)")
        return done

    def stats(self) -> dict:
        s = self.sched.summary()
        s.update({
            "prefill_calls": self.prefill_calls,
            "prefix_hits": self.prefix_hits,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "pages_shared": self.pages_shared,
            "cow_copies": getattr(self.kv, "cow_copies", 0),
            "peak_pool_pages": self.kv.allocator.peak_used,
        })
        return s

    def prune_finished(self) -> int:
        """Drop finished requests from the engine's history.  A
        long-lived engine keeps every request for ``stats()``; call
        this between runs to bound memory (returns the count pruned —
        their metrics leave ``stats()`` with them)."""
        done = [rid for rid, r in self.requests.items() if r.done]
        for rid in done:
            del self.requests[rid]
        self.sched.all = [r for r in self.sched.all if not r.done]
        return len(done)
