"""Continuous-batching serving engine over the paged KV cache — the
layer between the model's prefill/decode step functions and the
``launch/serve.py`` CLI (docs/continuous-batching.md).

One engine ``step()``:

  1. retire finished requests: free their pages, then either refill
     the row in place from the queue (steady state) or swap-shrink it
     out of the decode batch (tail drain — finished slots never feed
     another decode step);
  2. admit queued requests while slots and pages allow (page
     exhaustion = backpressure, the request stays queued);
  3. one batched decode over the resident rows — every row active,
     each at its own depth via the per-slot length vector that flows
     ``KVCache.idx (B,)`` -> per-slot RoPE positions -> per-slot ring
     writes -> the decode-attention kernel's ``n_valid`` scalar-
     prefetch vector.

Prefill runs one request at a time (B=1) into a fresh cache and the
result row is merged into the batch — so a request's tokens are
bitwise independent of whichever other requests happen to be resident
(the mixed-depth parity contract, asserted in
tests/test_paged_serving.py).  Prompts are right-padded to a compile
bucket (``prompt_bucket``) so prefill compiles once per bucket, not
once per prompt length; the true length is what gets stamped into the
merged row's ``idx``, so padded garbage positions are never attended.

Weights are pre-quantized at build exactly like the legacy Server
(``PrequantParams``; ``REPRO_SERVE_PREQUANT=0`` falls back to cached
scales).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime_flags import serve_prequant
from repro.train.steps import (
    make_decode_step,
    make_prefill_step,
    prequantize_params,
    serve_weight_scales,
)

from .paged_cache import (
    PAGE_SIZE,
    PagedKVCache,
    PageExhausted,
    SlotCapacityExceeded,
)
from .scheduler import Request, Scheduler

PROMPT_BUCKET = 16


def prepare_weights(cfg, params):
    """Build-time weight preparation shared by the engine and the
    legacy Server: pre-quantized fp8 payloads + scales by default,
    cached per-tensor scales under ``REPRO_SERVE_PREQUANT=0``.
    Returns (params_tree, scales, prequant_or_None)."""
    prequant = (prequantize_params(cfg, params)
                if serve_prequant() else None)
    if prequant is not None:
        return prequant.qweights, prequant.scales, prequant
    return params, serve_weight_scales(cfg, params), None


def greedy_sample(logits):
    """(B, 1, V) last-position logits -> (B,) next token ids."""
    return jnp.argmax(logits[:, -1], axis=-1)


class Engine:
    """Paged-KV continuous-batching engine (see module docstring)."""

    def __init__(self, cfg, params, num_slots: int, max_len: int, *,
                 page_size: int = PAGE_SIZE,
                 num_pages: int | None = None,
                 prompt_bucket: int = PROMPT_BUCKET,
                 eos_id: int | None = None):
        if cfg.input_mode != "tokens":
            raise ValueError(
                f"serving engine drives token models; {cfg.name} has "
                f"input_mode={cfg.input_mode!r}")
        self.cfg = cfg
        self.max_len = max_len
        self.num_slots = num_slots
        # recurrent state (RWKV / RG-LRU) integrates every prefill
        # token — padded garbage would corrupt it (attention caches
        # just mask it), so those families prefill at exact length
        # (one compile per distinct prompt length)
        self.prompt_bucket = (1 if cfg.family in ("ssm", "hybrid")
                              else prompt_bucket)
        self.eos_id = eos_id
        self.params, self.scales, self.prequant = \
            prepare_weights(cfg, params)
        self.prefill = jax.jit(make_prefill_step(cfg, max_len,
                                                 scales=self.scales))
        self.decode = jax.jit(make_decode_step(cfg, scales=self.scales),
                              donate_argnums=(1,))
        self.kv = PagedKVCache(cfg, max_len, num_slots,
                               page_size=page_size, num_pages=num_pages)
        self.sched = Scheduler()
        self.requests: dict[int, Request] = {}

    # -- admission -----------------------------------------------------
    def _total_tokens(self, req: Request) -> int:
        # worst-case resident K/V: prompt + every decode-step write
        # (the last generated token is sampled but never written)
        return req.prompt_len + req.max_new - 1

    def submit(self, requests: list[Request]) -> None:
        for req in requests:
            if req.eos_id is None:
                req.eos_id = self.eos_id
            total = self._total_tokens(req)
            if not self.kv.ring and total > self.kv.slot_tokens:
                raise SlotCapacityExceeded(
                    f"request {req.rid}: prompt {req.prompt_len} + "
                    f"max_new {req.max_new} needs {total} cache "
                    f"positions > slot capacity {self.kv.slot_tokens}")
            al = self.kv.allocator
            need = al.pages_needed(self.kv._resident(total))
            if need > al.num_pages:
                # can never be admitted: reject at submit instead of
                # letting head-of-line FIFO livelock the queue
                raise PageExhausted(
                    f"request {req.rid}: worst-case reservation of "
                    f"{need} pages exceeds the whole pool "
                    f"({al.num_pages} pages)")
            self.requests[req.rid] = req
        self.sched.submit(requests)

    def _bucket_len(self, n: int) -> int:
        c = self.kv.slot_tokens
        if n >= c:
            return n          # ring keep-last-C prefill path, exact
        return min(c, -(-n // self.prompt_bucket) * self.prompt_bucket)

    def _prefill_request(self, req: Request):
        """B=1 prefill of a (bucket-padded) prompt; returns the one-row
        caches.  Emits the request's first generated token (TTFT)."""
        n = req.prompt_len
        toks = np.zeros((1, self._bucket_len(n)), np.int32)
        toks[0, :n] = req.prompt
        logits, one = self.prefill(self.params, {"tokens":
                                                 jnp.asarray(toks)},
                                   jnp.int32(min(n, toks.shape[1]) - 1))
        self.sched.on_token(req, int(greedy_sample(logits)[0]))
        return one

    def _admissible_head(self) -> Request | None:
        head = self.sched.peek()
        if head is None:
            return None
        if not self.kv.can_admit(self._total_tokens(head)):
            return None       # page backpressure: stays queued
        return head

    # -- the engine step -----------------------------------------------
    def step(self) -> None:
        self._retire_and_refill()
        self._admit_new_rows()
        self._decode_once()

    def _retire_and_refill(self):
        row = 0
        while row < len(self.kv.rows):
            owner = self.kv.rows[row]
            if owner is not None and not self.requests[owner].done:
                row += 1
                continue
            if owner is not None:
                self.kv.release(row)
            if self._admissible_head() is not None:
                req = self.sched.pop()
                one = self._prefill_request(req)
                self.kv.refill(row, req.rid, one, req.prompt_len,
                               self._total_tokens(req))
                # the refill may itself already be done (max_new == 1
                # or instant EOS): the loop re-checks this row
            else:
                self.kv.shrink(row)
                # the swapped-in last row is re-checked at this index

    def _admit_new_rows(self):
        while len(self.kv.rows) < self.num_slots:
            if self._admissible_head() is None:
                break
            req = self.sched.pop()
            one = self._prefill_request(req)
            self.kv.append(req.rid, one, req.prompt_len,
                           self._total_tokens(req))
            if self.requests[req.rid].done:       # instant finish
                self._retire_and_refill()

    def _decode_once(self):
        rows = self.kv.rows
        if not rows:
            return
        last = np.array([[self.requests[r].out[-1]] for r in rows],
                        np.int32)
        logits, self.kv.caches = self.decode(
            self.params, self.kv.caches, jnp.asarray(last))
        self.kv.advance()
        nxt = np.asarray(greedy_sample(logits))
        for i, rid in enumerate(list(rows)):
            self.sched.on_token(self.requests[rid], int(nxt[i]))

    # -- driver --------------------------------------------------------
    def run(self, requests: list[Request] | None = None, log=print):
        """Drain the queue; returns the requests that finished during
        THIS call (an engine instance can serve several runs — the jit
        caches on its step functions carry over)."""
        if requests:
            self.submit(requests)
        done_before = {rid for rid, r in self.requests.items() if r.done}
        toks_before = sum(len(r.out) for r in self.requests.values())
        t0 = time.monotonic()
        steps = 0
        while self.sched.queue or self.kv.rows:
            self.step()
            steps += 1
            if steps > 100_000:
                raise RuntimeError("serving loop did not converge")
        dt = time.monotonic() - t0
        done = [r for rid, r in self.requests.items()
                if r.done and rid not in done_before]
        toks = sum(len(r.out) for r in self.requests.values()) \
            - toks_before
        if log is not None:
            ttfts = [r.ttft for r in done if r.ttft is not None]
            tpots = [r.tpot for r in done if r.tpot is not None]
            mean = lambda v: float(np.mean(v)) if v else float("nan")
            log(f"served {len(done)} requests, {toks} tokens in "
                f"{dt:.2f}s ({toks / max(dt, 1e-9):,.1f} tok/s, "
                f"{steps} engine steps, mean TTFT "
                f"{1e3 * mean(ttfts):.1f} ms, mean TPOT "
                f"{1e3 * mean(tpots):.1f} ms)")
        return done

    def stats(self) -> dict:
        return self.sched.summary()

    def prune_finished(self) -> int:
        """Drop finished requests from the engine's history.  A
        long-lived engine keeps every request for ``stats()``; call
        this between runs to bound memory (returns the count pruned —
        their metrics leave ``stats()`` with them)."""
        done = [rid for rid, r in self.requests.items() if r.done]
        for rid in done:
            del self.requests[rid]
        self.sched.all = [r for r in self.sched.all if not r.done]
        return len(done)
