"""Continuous-batching serving engine over the paged KV cache — the
layer between the model's step functions and the ``launch/serve.py``
CLI (docs/continuous-batching.md).

Scheduler v2 (the default, ``REPRO_CHUNKED_PREFILL``): one engine
``step()`` is

  1. retire finished requests (release pages, shrink them out of the
     decode batch);
  2. swap preempted requests back in when their pages fit again
     (FIFO over the preempted deque — they hold finished work);
  3. up to ``Scheduler.chunk_budget()`` CHUNKED-PREFILL steps: the
     staging request's next ``chunk_tokens`` prompt tokens run as one
     (1, chunk) decode-mode step writing at the request's own depth
     into its own pages (block-table scatter; padded tail garbage
     lands in the trash page), attending over its already-resident
     history.  The final chunk's last real logit is the request's
     first output token (stamps TTFT) and the request joins the
     decode batch at its true prompt length;
  4. one batched (B, 1) decode over the resident rows, every row at
     its own depth — or, with speculative decode on
     (``REPRO_SPEC_DECODE=1`` / ``Engine(spec_decode=True)``), one
     (B, k) VERIFY step: each row gambles up to ``k-1`` host-proposed
     draft tokens (greedy n-gram prompt lookup by default, or an
     injected draft model), all k positions run through ONE forward
     over the fp8 KV cache, and the longest draft prefix matching the
     model's own argmaxes commits together with the model's
     correction token.  Greedy output is token-for-token identical to
     plain decode; rejected drafts truncate for free (the per-slot
     length vector is the truth, docs/speculative-decoding.md).  The
     draft length adapts to the measured accept rate
     (``Scheduler.draft_len``).

One compiled mixed-step graph serves both shapes (3) and (4) — there
is no per-prompt-bucket prefill compile and no B=1 whole-prompt
stall; a long prompt's chunks interleave with other requests' decode
steps.  A prefix-cache hit (float placement, ``REPRO_PREFIX_CACHE``)
maps its page-aligned shared prefix copy-on-write and chunk-prefills
only the UNSHARED SUFFIX at an offset — the replay-through-decode
path this replaces is gone.

Admission is usage-based when preemption is on
(``REPRO_PREEMPTION``): a request reserves its prompt plus one page
of headroom instead of the worst case, and outgrowing the
reservation extends it page by page.  When an extension finds the
pool dry, the engine PREEMPTS: ``Scheduler.pick_victim`` chooses the
resident request with the most TPOT headroom, its pages are copied
to host (payloads and scales, bitwise) and freed, and the victim
parks in a deque until retirement frees enough pages to swap back in
and resume at its recorded depth.  A request whose worst case
exceeds the whole pool is still rejected at submit — so a lone
resident request always fits and the preempt-retry loop terminates.

``Request.arrival_time`` turns ``run()`` into an open-loop driver:
requests are submitted (and their TTFT clocks started) at their
trace offsets instead of all at once.

The v1 path (whole-prompt bucketed B=1 prefill, reservation-based
admission, no preemption) is kept verbatim behind
``REPRO_CHUNKED_PREFILL=0`` as the A/B baseline, and is the
automatic fallback for families the mixed step cannot serve
(recurrent states, MLA latent caches, windowed rings).

Weights are pre-quantized at build exactly like the legacy Server
(``PrequantParams``; ``REPRO_SERVE_PREQUANT=0`` falls back to cached
scales).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.actscale import calibrate_act_scales
from repro.core.runtime_flags import (
    chunked_prefill,
    paged_placement,
    quant_health,
    quant_health_every,
    serve_delayed_act,
    serve_preemption,
    serve_prefix_cache,
    serve_prequant,
)
from repro.core.runtime_flags import spec_decode as spec_decode_flag
from repro.models.transformer import (
    chunk_prefill_supported,
    init_caches,
    map_cache_nodes,
    paged_decode_supported,
    spec_verify_supported,
)
from repro.train.steps import (
    make_decode_step,
    make_prefill_step,
    make_verify_step,
    prequantize_params,
    serve_weight_scales,
)

from repro.obs.metrics import get_registry
from repro.obs.trace import instant, span

from .paged_cache import (
    PAGE_SIZE,
    FloatingPageCache,
    PagedKVCache,
    PageExhausted,
    SlotCapacityExceeded,
    page_keys,
)
from .scheduler import Request, RequestState, Scheduler, SLOTargets
from .spec import DraftSource, NgramDraft

PROMPT_BUCKET = 16
CHUNK_TOKENS = 32


def prepare_weights(cfg, params):
    """Build-time weight preparation shared by the engine and the
    legacy Server: pre-quantized fp8 payloads + scales by default,
    cached per-tensor scales under ``REPRO_SERVE_PREQUANT=0``.
    Returns (params_tree, scales, prequant_or_None)."""
    prequant = (prequantize_params(cfg, params)
                if serve_prequant() else None)
    if prequant is not None:
        return prequant.qweights, prequant.scales, prequant
    return params, serve_weight_scales(cfg, params), None


def calibrate_serving(cfg, params, scales):
    """Build-time delayed-activation-scale calibration shared by the
    engine and the legacy Server: one eager forward over the
    calibration prompt (``repro.core.actscale``) when
    ``REPRO_SERVE_DELAYED_ACT`` is on, else None (just-in-time
    activation scaling, the pre-delayed graphs bitwise)."""
    if not serve_delayed_act():
        return None
    return calibrate_act_scales(cfg, params, scales)


def greedy_sample(logits):
    """(B, 1, V) last-position logits -> (B,) next token ids."""
    return jnp.argmax(logits[:, -1], axis=-1)


@dataclasses.dataclass
class PrefixPlan:
    """Admission-time prefix-cache decision for one request.

    ``keys``        chained page hashes of every FULL prompt page
    ``pages``       physical pages hit (longest registered prefix run,
                    clamped to the prompt's full pages) — empty = cold
    ``suffix_from`` first prompt position that chunk-prefills:
                    ``min(n_shared*T, prompt_len - 1)`` (a FULL hit
                    still runs the last prompt token through a chunk,
                    whose sample is the first output)
    ``cow_slack``   1 when the suffix's first write lands inside a
                    shared page (full page-aligned hit), else 0 —
                    reserved so the copy-on-write can always
                    allocate"""
    keys: list
    pages: list
    suffix_from: int
    cow_slack: int


@dataclasses.dataclass
class _Staging:
    """The one request currently chunk-prefilling: its pages are
    admitted but it has no decode-batch row until the last chunk."""
    req: Request
    pos: int                  # next prompt position to chunk-prefill
    keys: list | None         # page hashes to publish at attach (float)
    row_cache: dict | None    # detached one-row caches (identity only)


class Engine:
    """Paged-KV continuous-batching engine (see module docstring)."""

    def __init__(self, cfg, params, num_slots: int, max_len: int, *,
                 page_size: int = PAGE_SIZE,
                 num_pages: int | None = None,
                 prompt_bucket: int = PROMPT_BUCKET,
                 chunk_tokens: int = CHUNK_TOKENS,
                 eos_id: int | None = None,
                 prefix_cache: bool | None = None,
                 slo: SLOTargets | None = None,
                 spec_decode: bool | None = None,
                 draft: DraftSource | None = None,
                 spec_k: int = 4):
        if cfg.input_mode != "tokens":
            raise ValueError(
                f"serving engine drives token models; {cfg.name} has "
                f"input_mode={cfg.input_mode!r}")
        self.cfg = cfg
        self.max_len = max_len
        self.num_slots = num_slots
        # recurrent state (RWKV / RG-LRU) integrates every prefill
        # token — padded garbage would corrupt it (attention caches
        # just mask it), so those families prefill at exact length
        # (one compile per distinct prompt length)
        self.prompt_bucket = (1 if cfg.family in ("ssm", "hybrid")
                              else prompt_bucket)
        self.eos_id = eos_id
        self.params, self.scales, self.prequant = \
            prepare_weights(cfg, params)
        self.act_scales = calibrate_serving(cfg, self.params,
                                            self.scales)
        self._build_steps()
        self.float_pages = (paged_placement() == "float"
                            and paged_decode_supported(cfg, max_len,
                                                       page_size))
        self.chunked = (chunked_prefill()
                        and chunk_prefill_supported(cfg, max_len))
        # preemption = usage-based admission + swap-to-host; both
        # live on the floating pool's block-table indirection
        self.preemption = (serve_preemption() and self.chunked
                           and self.float_pages)
        if self.float_pages:
            self.kv = FloatingPageCache(cfg, max_len, num_slots,
                                        page_size=page_size,
                                        num_pages=num_pages,
                                        usage_mode=self.preemption)
        else:
            self.kv = PagedKVCache(cfg, max_len, num_slots,
                                   page_size=page_size,
                                   num_pages=num_pages)
        # prefix hits are served by chunk-prefilling the unshared
        # suffix — no chunked prefill, no prefix cache
        self.prefix_cache = (self.float_pages and self.chunked
                             and (serve_prefix_cache()
                                  if prefix_cache is None
                                  else prefix_cache))
        self.chunk_tokens = max(1, min(chunk_tokens,
                                       self.kv.slot_tokens))
        # speculative multi-token decode (docs/speculative-decoding.md)
        # rides on the v2 mixed step: the verify graph needs per-slot
        # depths and an unwrapped cache, exactly the chunked-prefill
        # support surface.  Opt-in (constructor arg wins over the env
        # flag); greedy output stays token-for-token identical either
        # way, so the toggle is pure performance.
        # ... and batch-shape-independent activation scaling: with
        # just-in-time act amaxes a (B, k) verify window measures a
        # different per-tensor scale than the (B, 1) steps it
        # replaces, breaking token-for-token parity.  Delayed
        # (calibrated) scales — the serving default — or the bf16
        # pipeline are exact.
        self.spec = ((spec_decode if spec_decode is not None
                      else spec_decode_flag())
                     and self.chunked
                     and spec_verify_supported(cfg, max_len)
                     and (self.act_scales is not None
                          or cfg.quant.mode == "bf16"))
        self.draft: DraftSource = (draft if draft is not None
                                   else NgramDraft())
        self.spec_k = max(1, int(spec_k))
        self._staging: _Staging | None = None
        self._preempted: deque[tuple[Request, dict]] = deque()
        self.prefill_calls = 0
        self.prefill_tokens_skipped = 0
        self.prefix_hits = 0
        self.pages_shared = 0
        self.chunk_prefill_steps = 0
        self.chunked_requests = 0
        self.preemptions = 0
        self.swap_ins = 0
        self.sched = Scheduler(slo=slo)
        self.requests: dict[int, Request] = {}

    def _build_steps(self):
        # quant-health telemetry (docs/observability.md): resolved at
        # BUILD time so the off path's step graphs carry zero telemetry
        # code — the decode/verify jaxprs stay byte-identical to a
        # health-free build (tests/test_obs.py).  Needs the delayed
        # activation scales: the health stats measure drift AGAINST
        # them.  With health on the engine carries BOTH step variants
        # and runs the instrumented one every Nth call
        # (REPRO_QUANT_HEALTH_EVERY, default 16) — drift moves over
        # thousands of steps, so sparse sampling keeps the signal
        # while the hot loop runs the plain (telemetry-free) graphs.
        self.health = quant_health() and self.act_scales is not None
        self.health_every = quant_health_every() if self.health else 0
        # per-(step kind, token shape) countdowns: the FIRST call of
        # every distinct signature samples health, so a warmup pass
        # compiles every instrumented variant it will ever need and
        # steady state never jit-stalls mid-serving; short runs and
        # post-refresh rebuilds still report.
        self._health_countdown: dict = {}
        if self.health and getattr(self, "qh", None) is None:
            from repro.obs.quant_health import HealthAggregator

            self.qh = HealthAggregator()
        elif not self.health:
            self.qh = None
        self.prefill = jax.jit(
            make_prefill_step(self.cfg, self.max_len,
                              scales=self.scales,
                              act_scales=self.act_scales))
        self.decode = jax.jit(
            make_decode_step(self.cfg, scales=self.scales,
                             act_scales=self.act_scales),
            donate_argnums=(1,))
        # the speculative verify step ((B, k) tokens -> (B, k, V)
        # logits); jit is lazy, so non-speculative engines never
        # compile it
        self.verify = jax.jit(
            make_verify_step(self.cfg, scales=self.scales,
                             act_scales=self.act_scales),
            donate_argnums=(1,))
        if self.health:
            self.prefill_h = jax.jit(
                make_prefill_step(self.cfg, self.max_len,
                                  scales=self.scales,
                                  act_scales=self.act_scales,
                                  quant_health=True))
            self.decode_h = jax.jit(
                make_decode_step(self.cfg, scales=self.scales,
                                 act_scales=self.act_scales,
                                 quant_health=True),
                donate_argnums=(1,))
            self.verify_h = jax.jit(
                make_verify_step(self.cfg, scales=self.scales,
                                 act_scales=self.act_scales,
                                 quant_health=True),
                donate_argnums=(1,))

    # -- quant-health step-call shims ----------------------------------
    # Health OFF: the plain steps, exactly the historical 2-tuples.
    # Health ON: every Nth call runs the instrumented variant, whose
    # third output (the per-site stats tree) feeds the host-side
    # aggregator.
    def _health_due(self, kind: str, shape) -> bool:
        if not self.health:
            return False
        key = (kind, tuple(shape))
        cd = self._health_countdown.get(key)
        if cd is None or cd <= 0:
            self._health_countdown[key] = self.health_every
            return True
        self._health_countdown[key] = cd - 1
        return False

    def _run_prefill(self, *a):
        if self._health_due("prefill", a[1]["tokens"].shape):
            logits, caches, qh = self.prefill_h(*a)
            self.qh.ingest(qh)
            return logits, caches
        return self.prefill(*a)

    def _run_decode(self, *a):
        if self._health_due("decode", a[2].shape):
            logits, caches, qh = self.decode_h(*a)
            self.qh.ingest(qh)
            return logits, caches
        return self.decode(*a)

    def _run_verify(self, *a):
        if self._health_due("verify", a[2].shape):
            logits, caches, qh = self.verify_h(*a)
            self.qh.ingest(qh)
            return logits, caches
        return self.verify(*a)

    def refresh_act_scales(self, tokens=None, margin=None):
        """Re-calibrate the delayed activation scales (optionally on
        caller-supplied ``tokens``) and rebuild the jitted steps —
        runs entirely OUTSIDE the hot decode jaxpr.  No-op when
        delayed scaling is off."""
        if self.act_scales is None:
            return None
        kw = {} if margin is None else {"margin": margin}
        self.act_scales = calibrate_act_scales(
            self.cfg, self.params, self.scales, tokens=tokens, **kw)
        self._build_steps()
        return self.act_scales

    # -- admission -----------------------------------------------------
    def _total_tokens(self, req: Request) -> int:
        # worst-case resident K/V: prompt + every decode-step write
        # (the last generated token is sampled but never written)
        return req.prompt_len + req.max_new - 1

    def _admit_tokens(self, req: Request) -> int:
        """The token count admission reserves pages for: actual usage
        (prompt) plus one page of headroom under preemption — growth
        past it extends page by page, preempting on a dry pool — or
        the worst case when preemption is off (reservation-based
        admission is then the no-corruption guarantee)."""
        total = self._total_tokens(req)
        if self.preemption:
            return min(total, req.prompt_len + self.kv.page_size)
        return total

    def submit(self, requests: list[Request]) -> None:
        for req in requests:
            if req.eos_id is None:
                req.eos_id = self.eos_id
            total = self._total_tokens(req)
            if not self.kv.ring and total > self.kv.slot_tokens:
                raise SlotCapacityExceeded(
                    f"request {req.rid}: prompt {req.prompt_len} + "
                    f"max_new {req.max_new} needs {total} cache "
                    f"positions > slot capacity {self.kv.slot_tokens}")
            al = self.kv.allocator
            need = al.pages_needed(self.kv._resident(total))
            if need > al.num_pages:
                # can never be admitted — even alone in an empty pool
                # (this reject is also what makes the preempt-retry
                # loop terminate: a lone resident request always
                # fits): reject at submit instead of letting
                # head-of-line FIFO livelock the queue
                raise PageExhausted(
                    f"request {req.rid}: worst-case reservation of "
                    f"{need} pages exceeds the whole pool "
                    f"({al.num_pages} pages)")
            self.requests[req.rid] = req
        self.sched.submit(requests)

    def _prefix_plan(self, req: Request) -> PrefixPlan | None:
        """Look the request's page-aligned prompt prefix up in the
        hash map (None when prefix caching is off)."""
        if not self.prefix_cache:
            return None
        t = self.kv.page_size
        keys = page_keys(req.prompt, t)
        pages = self.kv.allocator.lookup(keys)
        n_shared = len(pages)
        if n_shared == 0:
            return PrefixPlan(keys=keys, pages=[], suffix_from=0,
                              cow_slack=0)
        suffix_from = min(n_shared * t, req.prompt_len - 1)
        cow_slack = 1 if n_shared * t >= req.prompt_len else 0
        return PrefixPlan(keys=keys, pages=pages,
                          suffix_from=suffix_from, cow_slack=cow_slack)

    # -- the engine step -----------------------------------------------
    def step(self) -> None:
        # spans wrap the HOST-side phases (repro.obs.trace) — never
        # anything inside a jitted graph, so REPRO_TRACE can never
        # change a jaxpr
        with span("engine.step", rows=len(self.kv.rows)):
            if not self.chunked:
                with span("retire_refill"):
                    self._retire_and_refill()
                    self._admit_new_rows()
                with span("decode", rows=len(self.kv.rows)):
                    self._decode_once()
                return
            with span("retire"):
                self._retire()
            with span("swap_in", preempted=len(self._preempted)):
                self._swap_in_preempted()
            with span("chunk_phase"):
                self._chunk_phase()
            with span("retire"):
                self._retire()  # an attached request may finish
            if self.spec:       # instantly (max_new == 1 / EOS)
                with span("verify", rows=len(self.kv.rows)):
                    self._verify_once()
            else:
                with span("decode", rows=len(self.kv.rows)):
                    self._decode_once()

    # -- v2: retirement ------------------------------------------------
    def _retire(self):
        row = 0
        while row < len(self.kv.rows):
            if self.requests[self.kv.rows[row]].done:
                self.kv.release(row)
                self.kv.shrink(row)   # swapped-in last row re-checked
            else:
                row += 1

    # -- v2: preemption ------------------------------------------------
    def _swap_in_preempted(self):
        """Resume preempted requests FIFO while their pages fit.  One
        slot stays reserved for the in-flight staging request — its
        attach must never find the batch full."""
        while self._preempted:
            limit = self.num_slots - (self._staging is not None)
            if len(self.kv.rows) >= limit:
                return
            req, bundle = self._preempted[0]
            admit = (min(self._total_tokens(req),
                         bundle["depth"] + self.kv.page_size)
                     if self.preemption else self._total_tokens(req))
            try:
                self.kv.swap_in(bundle, admit)
            except PageExhausted:
                return            # stays parked; retirement frees pages
            self._preempted.popleft()
            req.state = RequestState.RUNNING
            self.swap_ins += 1

    def _preempt_one(self) -> bool:
        """Swap the SLO-chosen victim out to host; False when the
        decode batch has nobody left to preempt."""
        cands = [self.requests[rid] for rid in self.kv.rows
                 if rid is not None]
        victim = self.sched.pick_victim(cands)
        if victim is None:
            return False
        bundle = self.kv.swap_out(self.kv.rows.index(victim.rid))
        victim.state = RequestState.PREEMPTED
        self._preempted.append((victim, bundle))
        self.preemptions += 1
        instant("preempt", rid=victim.rid, depth=bundle["depth"])
        return True

    def _grow_or_preempt(self, grow) -> None:
        """Run a page-growing cache operation, preempting one victim
        per ``PageExhausted`` until it fits.  Terminates: every
        preemption frees pages, and a lone resident request always
        fits (submit-time whole-pool reject)."""
        while True:
            try:
                grow()
                return
            except PageExhausted:
                if not (self.preemption and self._preempt_one()):
                    raise

    # -- v2: chunked prefill -------------------------------------------
    def _begin_staging(self) -> bool:
        """Pop the queue head into the staging slot when it fits under
        ACTUAL free-page accounting (usage-based under preemption).
        Preempted requests drain first — they hold finished work, and
        refusing new admissions while any are parked guarantees their
        re-admission is never starved."""
        if self._preempted:
            return False
        head = self.sched.peek()
        if head is None or len(self.kv.rows) >= self.num_slots:
            return False
        plan = self._prefix_plan(head)
        admit = self._admit_tokens(head)
        if plan is not None and plan.pages:
            ok = self.kv.can_admit(admit, shared=plan.pages,
                                   cow_slack=plan.cow_slack)
            if not ok and self.kv.can_admit(admit):
                # the hit needs MORE headroom than a cold admit (page
                # revival + CoW slack, e.g. a minimal pool): serve it
                # cold rather than livelock the FIFO head forever
                plan = PrefixPlan(keys=plan.keys, pages=[],
                                  suffix_from=0, cow_slack=0)
                ok = True
        else:
            ok = self.kv.can_admit(admit)
        if not ok:
            return False          # stays queued (backpressure)
        req = self.sched.pop()
        pos, keys, row_cache = 0, None, None
        if self.float_pages:
            shared = plan.pages if plan is not None else []
            self.kv.stage_admit(req.rid, admit, shared=shared,
                                cow_slack=plan.cow_slack
                                if plan is not None else 0)
            keys = plan.keys if plan is not None else None
            if shared:
                pos = plan.suffix_from
                self.prefix_hits += 1
                self.prefill_tokens_skipped += pos
                self.pages_shared += len(shared)
                req.prefix_pages = len(shared)
                req.prefill_skipped = pos
        else:
            self.kv.stage_admit(req.rid, admit)
            row_cache = init_caches(self.cfg, 1, self.max_len,
                                    per_slot=True)
        self._staging = _Staging(req=req, pos=pos, keys=keys,
                                 row_cache=row_cache)
        self.chunked_requests += 1
        return True

    def _chunk_step(self) -> None:
        """One (1, chunk_tokens) prefill chunk of the staging request:
        write its next prompt tokens at its own depth, attend over its
        resident history.  The final chunk emits the first output
        token and attaches the request to the decode batch."""
        st = self._staging
        req, plen = st.req, st.req.prompt_len
        chunk = self.chunk_tokens
        n_real = min(chunk, plen - st.pos)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :n_real] = req.prompt[st.pos:st.pos + n_real]
        if self.float_pages:
            self._grow_or_preempt(
                lambda: self.kv.stage_ensure(req.rid, st.pos,
                                             st.pos + n_real))
            self.kv.stage_stamp(req.rid, st.pos)
            logits, self.kv.caches = self._run_decode(
                self.params, self.kv.caches, jnp.asarray(toks))
        else:
            # identity placement: the chunk runs on a detached one-row
            # cache; only the depth stamp moves between chunks
            st.row_cache = {
                name: map_cache_nodes(
                    seg, lambda n: n._replace(
                        idx=jnp.full_like(n.idx, st.pos)))
                if seg is not None else None
                for name, seg in st.row_cache.items()}
            logits, st.row_cache = self._run_decode(
                self.params, st.row_cache, jnp.asarray(toks))
        self.chunk_prefill_steps += 1
        st.pos += n_real
        if st.pos < plen:
            return
        # last chunk: its final real logit is the first output token
        first = int(jnp.argmax(logits[0, n_real - 1]))
        if self.float_pages:
            self.kv.stage_attach(req.rid, plen)
            if st.keys:
                self.kv.register_prompt(req.rid, st.keys)
        else:
            self.kv.stage_attach(req.rid, st.row_cache, plen)
        self._staging = None
        self.sched.on_token(req, first)

    def _chunk_phase(self):
        budget = self.sched.chunk_budget()
        while budget > 0:
            if self._staging is None and not self._begin_staging():
                return
            self._chunk_step()
            budget -= 1

    # -- v1: whole-prompt prefill admission (A/B fallback) -------------
    def _bucket_len(self, n: int) -> int:
        c = self.kv.slot_tokens
        if n >= c:
            return n          # ring keep-last-C prefill path, exact
        return min(c, -(-n // self.prompt_bucket) * self.prompt_bucket)

    def _prefill_request(self, req: Request):
        """B=1 prefill of a (bucket-padded) prompt; returns the one-row
        caches.  Emits the request's first generated token (TTFT)."""
        n = req.prompt_len
        toks = np.zeros((1, self._bucket_len(n)), np.int32)
        toks[0, :n] = req.prompt
        logits, one = self._run_prefill(self.params, {"tokens":
                                                 jnp.asarray(toks)},
                                   jnp.int32(min(n, toks.shape[1]) - 1))
        self.prefill_calls += 1
        self.sched.on_token(req, int(greedy_sample(logits)[0]))
        return one

    def _admissible_head(self):
        """The head request when it fits under the pool's actual
        free-page accounting, else None."""
        head = self.sched.peek()
        if head is None or not self.kv.can_admit(
                self._total_tokens(head)):
            return None
        return head

    def _admit(self, req: Request, row: int | None = None) -> None:
        """Admit one popped request: B=1 whole-prompt prefill, then
        merge/scatter the row."""
        one = self._prefill_request(req)
        total = self._total_tokens(req)
        if row is None:
            self.kv.append(req.rid, one, req.prompt_len, total)
        else:
            self.kv.refill(row, req.rid, one, req.prompt_len, total)

    def _retire_and_refill(self):
        row = 0
        while row < len(self.kv.rows):
            owner = self.kv.rows[row]
            if owner is not None and not self.requests[owner].done:
                row += 1
                continue
            if owner is not None:
                self.kv.release(row)
            head = self._admissible_head()
            if head is not None:
                self._admit(self.sched.pop(), row=row)
                # a refill may itself already be done (max_new == 1
                # or instant EOS): the loop re-checks this row
            else:
                self.kv.shrink(row)
                # the swapped-in last row is re-checked at this index

    def _admit_new_rows(self):
        while len(self.kv.rows) < self.num_slots:
            head = self._admissible_head()
            if head is None:
                break
            self._admit(self.sched.pop())
            if head.done:                         # instant finish
                self._retire_and_refill()

    # -- decode --------------------------------------------------------
    def _decode_once(self):
        if self.float_pages:
            # copy-on-write barrier + idx/block-table restamp: every
            # row's write-target page must be private BEFORE the
            # in-graph append.  Growth past a usage reservation can
            # exhaust the pool — preempt a victim and retry (the
            # ensure pass is idempotent across retries)
            self._grow_or_preempt(
                lambda: self.kv.prepare_decode()
                if self.kv.rows else None)
        rows = self.kv.rows
        if not rows:
            return
        feed = np.zeros((len(rows), 1), np.int32)
        for i, rid in enumerate(rows):
            feed[i, 0] = self.requests[rid].out[-1]
        logits, self.kv.caches = self._run_decode(
            self.params, self.kv.caches, jnp.asarray(feed))
        self.kv.advance()
        nxt = np.asarray(greedy_sample(logits))
        for i, rid in enumerate(list(rows)):
            self.sched.on_token(self.requests[rid], int(nxt[i]))

    # -- speculative verify (docs/speculative-decoding.md) -------------
    def _verify_once(self):
        """One speculative verify step over the resident rows: propose
        up to ``k-1`` draft tokens per row, run ALL ``k`` positions
        ([last output, drafts...]) through ONE (B, k) forward over the
        paged fp8 cache, and commit per row the longest draft prefix
        matching the model's own argmaxes plus the model's correction
        token.  Greedy output is token-for-token identical to plain
        decode — position j's logits equal the sequential step's
        because the per-draft kernel mask reproduces each step's
        validity window exactly (docs/speculative-decoding.md).

        ``k`` is clamped so NO row can overrun its ``max_new`` budget
        or its slot's write window, and collapses to a plain
        ``_decode_once`` (same compiled (B, 1) graph) when the clamp
        or an empty proposal round leaves nothing to gamble on."""
        rows = self.kv.rows
        if not rows:
            return
        reqs = [self.requests[rid] for rid in rows]
        k = self.sched.draft_len(self.spec_k)
        for i, r in enumerate(reqs):
            # a k-step commits up to k tokens and writes k positions:
            # stay inside every row's generation budget and its slot
            k = min(k, r.max_new - len(r.out),
                    self.kv.slot_tokens - self.kv.lengths[i])
        props = ([list(self.draft.propose(r, k - 1))[:k - 1]
                  for r in reqs] if k > 1 else [])
        if k > 1:
            k = min(k, 1 + max(len(p) for p in props))
        if k <= 1:
            self._decode_once()
            return
        feed = np.zeros((len(rows), k), np.int32)
        n_prop = []
        for i, r in enumerate(reqs):
            feed[i, 0] = r.out[-1]
            p = props[i][:k - 1]
            n_prop.append(len(p))
            # unproposed tail slots stay zero-padded: a pad token only
            # commits on a coincidental argmax match, which is by
            # definition the token plain decode would have produced
            feed[i, 1:1 + len(p)] = p
        if self.float_pages:
            # CoW barrier + restamp over the FULL k-token write window
            self._grow_or_preempt(
                lambda: self.kv.prepare_decode(write_tokens=k))
        logits, self.kv.caches = self._run_verify(
            self.params, self.kv.caches, jnp.asarray(feed))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))      # (B, k)
        advs, accepted = [], 0
        for i, rid in enumerate(list(rows)):
            req = self.requests[rid]
            drafts_in, done, j = 0, False, 0
            # accept drafts while they match the model's own argmax:
            # logits[i, j] is the model's prediction AFTER consuming
            # feed[i, :j+1], i.e. exactly the sequential step's logits
            while j < k - 1 and int(feed[i, j + 1]) == int(nxt[i, j]):
                done = self.sched.on_token(req, int(feed[i, j + 1]))
                drafts_in += 1
                j += 1
                if done:
                    break         # EOS / budget inside the window
            if not done:
                # first mismatch (or window exhausted): the model's
                # correction token — always committable, so a verify
                # step never stalls
                self.sched.on_token(req, int(nxt[i, j]))
            # cache depth advances one position per committed token
            # whose KV the step wrote: out[-1] + accepted drafts (the
            # correction token's KV, like plain decode's sample, waits
            # for the next step's write)
            advs.append(drafts_in + (0 if done else 1))
            accepted += min(drafts_in, n_prop[i])
        self.kv.commit(advs)
        # denominator = the whole (k-1)·B draft window, so padded
        # slots count as misses and the EMA shortens k when the draft
        # source cannot fill the window
        self.sched.on_verify((k - 1) * len(rows), accepted)

    # -- driver --------------------------------------------------------
    def _idle(self) -> bool:
        return not (self.sched.queue or self.kv.rows
                    or self._staging is not None or self._preempted)

    def run(self, requests: list[Request] | None = None, log=print):
        """Drain the queue; returns the requests that finished during
        THIS call (an engine instance can serve several runs — the jit
        caches on its step functions carry over).  Requests with an
        ``arrival_time`` are submitted open-loop at that offset from
        the call's start; the rest are submitted up front."""
        requests = requests or []
        pending = deque(sorted(
            (r for r in requests if r.arrival_time is not None),
            key=lambda r: r.arrival_time))
        now_batch = [r for r in requests if r.arrival_time is None]
        if now_batch:
            self.submit(now_batch)
        done_before = {rid for rid, r in self.requests.items() if r.done}
        toks_before = sum(len(r.out) for r in self.requests.values())
        t0 = time.monotonic()
        steps = 0
        while pending or not self._idle():
            now = time.monotonic() - t0
            while pending and pending[0].arrival_time <= now:
                self.submit([pending.popleft()])
            if self._idle():
                # nothing resident and the next arrival is in the
                # future: sleep toward it instead of spinning
                time.sleep(min(pending[0].arrival_time - now, 0.05))
                continue
            self.step()
            steps += 1
            if steps > 100_000:
                raise RuntimeError("serving loop did not converge")
        dt = time.monotonic() - t0
        done = [r for rid, r in self.requests.items()
                if r.done and rid not in done_before]
        toks = sum(len(r.out) for r in self.requests.values()) \
            - toks_before
        if log is not None:
            ttfts = [r.ttft for r in done if r.ttft is not None]
            tpots = [r.tpot for r in done if r.tpot is not None]
            mean = lambda v: float(np.mean(v)) if v else float("nan")
            log(f"served {len(done)} requests, {toks} tokens in "
                f"{dt:.2f}s ({toks / max(dt, 1e-9):,.1f} tok/s, "
                f"{steps} engine steps, mean TTFT "
                f"{1e3 * mean(ttfts):.1f} ms, mean TPOT "
                f"{1e3 * mean(tpots):.1f} ms)")
        return done

    def stats(self) -> dict:
        s = self.sched.summary()
        al = self.kv.allocator
        s.update({
            "prefill_calls": self.prefill_calls,
            "chunk_prefill_steps": self.chunk_prefill_steps,
            "chunked_requests": self.chunked_requests,
            "preemptions": self.preemptions,
            "swap_ins": self.swap_ins,
            "prefix_hits": self.prefix_hits,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "pages_shared": self.pages_shared,
            "cow_copies": getattr(self.kv, "cow_copies", 0),
            "page_evictions": al.evictions,
            "peak_pool_pages": al.peak_used,
        })
        if self.qh is not None:
            s["quant_health"] = {
                "refresh_recommended": self.qh.refresh_recommended,
                "sites": self.qh.report(),
            }
        self._publish_metrics(s, al)
        return s

    def _publish_metrics(self, s: dict, al) -> None:
        """Mirror the engine/allocator stats into the process-wide
        metrics registry (repro.obs.metrics) — ``set_total`` adopts
        the running python counters without double counting, so
        ``stats()`` can be called any number of times."""
        reg = get_registry()
        for name in ("prefill_calls", "chunk_prefill_steps",
                     "chunked_requests", "preemptions", "swap_ins",
                     "prefix_hits", "prefill_tokens_skipped",
                     "pages_shared", "cow_copies", "page_evictions"):
            reg.counter(f"engine_{name}_total").set_total(float(s[name]))
        reg.gauge("pages_total").set(float(al.num_pages))
        reg.gauge("pages_in_use").set(float(al.num_pages - al.free_pages))
        reg.gauge("pages_cached").set(float(al.cached_pages))
        reg.gauge("pages_peak_used").set(float(al.peak_used))
        reg.gauge("engine_resident_rows").set(float(len(self.kv.rows)))

    def prune_finished(self) -> int:
        """Drop finished requests from the engine's history.  A
        long-lived engine keeps every request for ``stats()``; call
        this between runs to bound memory (returns the count pruned —
        their metrics leave ``stats()`` with them)."""
        done = [rid for rid, r in self.requests.items() if r.done]
        for rid in done:
            del self.requests[rid]
        self.sched.all = [r for r in self.sched.all if not r.done]
        return len(done)
