"""Paged-KV continuous-batching serving engine
(docs/continuous-batching.md, docs/paged-attention.md).

- ``paged_cache`` — free-list page allocator with refcounts +
  copy-on-write prefix sharing (``PageAllocator``), the floating
  global page pool (``FloatingPageCache``) and the identity-placement
  per-slot rows (``PagedKVCache``);
- ``scheduler`` — FIFO admission, EOS/max_new retirement, TTFT/TPOT
  metrics and the SLO policies built on them (``Scheduler``,
  ``Request``, ``SLOTargets``);
- ``engine`` — chunked prefill interleaved with batched decode over
  the per-slot length vector, preemption with page swap-to-host
  (``Engine``; ``REPRO_CHUNKED_PREFILL=0`` keeps the v1 whole-prompt
  prefill path as the A/B baseline);
- ``spec`` — draft sources for speculative multi-token decode
  (``NgramDraft`` greedy prompt-lookup, ``ModelDraft`` small-model
  hook; docs/speculative-decoding.md).  Opt-in via
  ``REPRO_SPEC_DECODE=1`` or ``Engine(spec_decode=True)``; greedy
  output is token-for-token identical to plain decode.

``launch/serve.py`` is the CLI over this package; the legacy
contiguous-ring ``Server`` there is the ``REPRO_SERVE_PAGED=0``
fallback.
"""

from .engine import Engine, PrefixPlan, greedy_sample, prepare_weights
from .paged_cache import (
    PAGE_SIZE,
    BlockTable,
    FloatingPageCache,
    PageAllocator,
    PagedCacheError,
    PagedKVCache,
    PageExhausted,
    SlotCapacityExceeded,
    page_keys,
)
from .scheduler import Request, RequestState, Scheduler, SLOTargets
from .spec import DraftSource, ModelDraft, NgramDraft

__all__ = [
    "DraftSource",
    "ModelDraft",
    "NgramDraft",
    "Engine",
    "PrefixPlan",
    "greedy_sample",
    "prepare_weights",
    "PAGE_SIZE",
    "BlockTable",
    "FloatingPageCache",
    "PageAllocator",
    "PagedCacheError",
    "PagedKVCache",
    "PageExhausted",
    "SlotCapacityExceeded",
    "page_keys",
    "Request",
    "RequestState",
    "Scheduler",
    "SLOTargets",
]
