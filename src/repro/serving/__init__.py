"""Paged-KV continuous-batching serving engine
(docs/continuous-batching.md).

- ``paged_cache`` — block-table page accounting (``PageAllocator``)
  over the per-slot device cache rows (``PagedKVCache``);
- ``scheduler`` — FIFO admission, EOS/max_new retirement, TTFT/TPOT
  metrics (``Scheduler``, ``Request``);
- ``engine`` — prefill-into-slot + batched decode over the per-slot
  length vector (``Engine``).

``launch/serve.py`` is the CLI over this package; the legacy
contiguous-ring ``Server`` there is the ``REPRO_SERVE_PAGED=0``
fallback.
"""

from .engine import Engine, greedy_sample, prepare_weights
from .paged_cache import (
    PAGE_SIZE,
    BlockTable,
    PageAllocator,
    PagedCacheError,
    PagedKVCache,
    PageExhausted,
    SlotCapacityExceeded,
)
from .scheduler import Request, RequestState, Scheduler

__all__ = [
    "Engine",
    "greedy_sample",
    "prepare_weights",
    "PAGE_SIZE",
    "BlockTable",
    "PageAllocator",
    "PagedCacheError",
    "PagedKVCache",
    "PageExhausted",
    "SlotCapacityExceeded",
    "Request",
    "RequestState",
    "Scheduler",
]
