"""Paged KV cache: block-table page accounting + the per-slot device
cache it governs (docs/continuous-batching.md).

Two layers, deliberately separate:

``PageAllocator`` (host-side bookkeeping)
    A vLLM-style block-table allocator over a pool of fixed-size pages
    (``page_size`` tokens each).  Admission reserves a request's
    worst-case page count (prompt + max_new, clamped to the slot's
    ring capacity) so decode can never run out mid-request — there is
    no preemption in this engine, so reservation-based admission is
    the no-corruption guarantee.  Physical pages are allocated lazily
    as the sequence actually grows and freed on retirement.  The pool
    may be smaller than ``num_slots`` full rows (over-committed slots
    — the vLLM memory argument: mean sequence length < capacity), in
    which case admission backpressure, not slot count, bounds
    concurrency.

``PagedKVCache`` (device rows + lengths)
    The device-side cache keeps the existing kv-head-major
    ``(B, KV, C, Dh)`` payload + scale layout — one contiguous row
    per slot — with the per-slot length vector (``KVCache.idx`` as a
    ``(B,)`` vector) carrying each row's depth.  A slot's logical
    page j therefore maps to byte range ``[j*page, (j+1)*page)`` of
    its own row: the block table is real accounting over an
    identity physical mapping.  Letting pages float across rows
    (true non-contiguous placement) requires block-table indirection
    inside the decode kernel and is the ROADMAP follow-up; every
    interface here (admission, growth, release, exhaustion) is
    already expressed in pages so that change stays below this API.

    The row dimension is *dynamic*: admission appends a row, and
    retiring a finished request removes its row (the last row is
    swapped in, then the batch shrinks) — finished slots never feed
    another decode step.  jit recompiles per row count; counts only
    walk 1..num_slots so the compile set is bounded and reused across
    the serving run.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.attention import cache_len
from repro.models.transformer import map_cache_nodes

PAGE_SIZE = 16


class PagedCacheError(RuntimeError):
    pass


class PageExhausted(PagedCacheError):
    """The page pool cannot cover the requested reservation —
    admission-time backpressure (the scheduler keeps the request
    queued instead of corrupting a resident slot)."""


class SlotCapacityExceeded(PagedCacheError):
    """A sequence would outgrow its slot's ring capacity C on a
    non-windowed arch — writing on would wrap the ring and silently
    clobber live positions, so this raises *before* corruption."""


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-max(n_tokens, 0) // page_size)


@dataclasses.dataclass
class BlockTable:
    """One slot's logical->physical page map.  ``pages[j]`` is the
    physical page id backing tokens [j*page_size, (j+1)*page_size)."""
    owner: int
    pages: list[int] = dataclasses.field(default_factory=list)
    reserved: int = 0          # worst-case pages admission committed to


class PageAllocator:
    """Fixed-size-page pool accounting with reservation-based
    admission (see module docstring)."""

    def __init__(self, num_pages: int, page_size: int = PAGE_SIZE,
                 slot_tokens: int | None = None):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        # per-slot ring capacity in tokens; None = unbounded rows
        self.slot_tokens = slot_tokens
        self._free = list(range(num_pages - 1, -1, -1))
        self._tables: dict[int, BlockTable] = {}
        self._committed = 0        # sum of outstanding reservations

    # -- introspection -------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def committed_pages(self) -> int:
        return self._committed

    def table(self, owner: int) -> BlockTable:
        return self._tables[owner]

    def _clamp(self, n_tokens: int) -> int:
        if self.slot_tokens is None:
            return n_tokens
        return min(n_tokens, self.slot_tokens)

    def pages_needed(self, n_tokens: int) -> int:
        return pages_for(self._clamp(n_tokens), self.page_size)

    # -- lifecycle -----------------------------------------------------
    def can_admit(self, total_tokens: int) -> bool:
        """Whether a request whose lifetime resident size is
        ``total_tokens`` fits under the outstanding reservations."""
        return (self._committed + self.pages_needed(total_tokens)
                <= self.num_pages)

    def admit(self, owner: int, prompt_tokens: int,
              total_tokens: int) -> BlockTable:
        """Reserve ``total_tokens`` worth of pages and allocate the
        prompt's pages now.  Raises ``PageExhausted`` when the pool
        cannot cover the reservation."""
        assert owner not in self._tables, f"owner {owner} already resident"
        need = self.pages_needed(total_tokens)
        if self._committed + need > self.num_pages:
            raise PageExhausted(
                f"reservation of {need} pages for owner {owner} exceeds "
                f"pool ({self._committed}/{self.num_pages} committed)")
        bt = BlockTable(owner=owner, reserved=need)
        self._tables[owner] = bt
        self._committed += need
        self._alloc_to(bt, self.pages_needed(prompt_tokens))
        return bt

    def grow(self, owner: int, resident_tokens: int) -> None:
        """Back ``resident_tokens`` with physical pages (one decode
        step usually crosses a page boundary every ``page_size``
        steps).  Raises ``SlotCapacityExceeded`` past the slot ring
        and ``PageExhausted`` if growth outruns the reservation into
        an empty pool (impossible under reservation-based admission —
        kept as the corruption guard for direct callers)."""
        if (self.slot_tokens is not None
                and resident_tokens > self.slot_tokens):
            raise SlotCapacityExceeded(
                f"owner {owner}: {resident_tokens} tokens > slot ring "
                f"capacity {self.slot_tokens} (ring wrap would clobber "
                f"live positions)")
        self._alloc_to(self._tables[owner],
                       self.pages_needed(resident_tokens))

    def _alloc_to(self, bt: BlockTable, n_pages: int) -> None:
        while len(bt.pages) < n_pages:
            if not self._free:
                raise PageExhausted(
                    f"pool empty growing owner {bt.owner} to "
                    f"{n_pages} pages")
            bt.pages.append(self._free.pop())

    def release(self, owner: int) -> int:
        """Free a retired request's pages + reservation; returns the
        number of physical pages returned to the pool."""
        bt = self._tables.pop(owner)
        self._free.extend(reversed(bt.pages))
        self._committed -= bt.reserved
        return len(bt.pages)


# ---------------------------------------------------------------------------
# Device-row helpers (jitted; recompiled per row count, which only
# walks 1..num_slots).  Stacked cache leaves are (L, B, ...) with the
# slot/row dim at axis 1; idx leaves are (L, B) vs the one-row
# prefill's (L,) — the one structural asymmetry the tree.maps key on.
# ---------------------------------------------------------------------------


def _stamp_idx(one, length):
    """One-row prefill caches arrive with idx = padded prompt length
    (the engine right-pads prompts to a compile bucket); stamp the TRUE
    length so validity masking hides the padded garbage positions."""
    return map_cache_nodes(
        one, lambda n: n._replace(idx=jnp.full(
            (n.idx.shape[0], 1), length, jnp.int32)))


@functools.partial(jax.jit, donate_argnums=(0,))
def _first_row(one, length):
    return _stamp_idx(one, length)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _append_row(big, one, length):
    one = _stamp_idx(one, length)

    def f(a, o):
        return jnp.concatenate([a, o.astype(a.dtype)], axis=1)

    return jax.tree.map(f, big, one)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _write_row(big, one, row, length):
    # after _stamp_idx every leaf of `one` is (L, 1, ...) against the
    # big tree's (L, B, ...) — idx included — so one update rule fits
    one = _stamp_idx(one, length)

    def f(a, o):
        return jax.lax.dynamic_update_slice_in_dim(
            a, o.astype(a.dtype), row, axis=1)

    return jax.tree.map(f, big, one)


# public alias: the legacy Server merges prefilled rows with the same
# helper (one source of the slot-write/idx-stamp semantics)
write_row = _write_row


@jax.jit
def _swap_shrink(big, row):
    """Move the last row into ``row`` and drop the last row — retiring
    a finished slot from the decode batch (wasted-FLOP satellite).
    Not donated: every output leaf is one row smaller than the input,
    so the buffers could never be reused anyway."""

    def f(a):
        a = a.at[:, row].set(a[:, -1])
        return jax.lax.slice_in_dim(a, 0, a.shape[1] - 1, axis=1)

    return jax.tree.map(f, big)


class PagedKVCache:
    """Per-slot device cache rows + lengths, governed by a
    ``PageAllocator`` (see module docstring).  ``rows[i]`` is the
    owner id (request rid) resident in device row i, or None for a
    released row awaiting refill/shrink within an engine step."""

    def __init__(self, cfg, max_len: int, num_slots: int,
                 page_size: int = PAGE_SIZE,
                 num_pages: int | None = None):
        self.cfg = cfg
        self.max_len = max_len
        self.num_slots = num_slots
        self.slot_tokens = cache_len(cfg, max_len)    # ring capacity C
        self.ring = self.slot_tokens < max_len        # window arch
        slot_pages = pages_for(self.slot_tokens, page_size)
        if num_pages is None:
            num_pages = num_slots * slot_pages        # fully backed
        self.allocator = PageAllocator(
            num_pages, page_size,
            # windowed rings wrap by design — growth clamps instead of
            # raising; non-windowed rows raise before corruption
            slot_tokens=None if self.ring else self.slot_tokens)
        self._ring_clamp = self.slot_tokens
        self.caches = None          # stacked device tree, rows = len(rows)
        self.rows: list[int | None] = []
        self.lengths: list[int] = []

    # -- admission -----------------------------------------------------
    def _resident(self, n_tokens: int) -> int:
        return min(n_tokens, self._ring_clamp)

    def can_admit(self, total_tokens: int) -> bool:
        """A slot (fresh row or released row awaiting refill) AND a
        page reservation are both available."""
        has_slot = len(self.rows) < self.num_slots or None in self.rows
        return has_slot and self.allocator.can_admit(
            self._resident(total_tokens))

    def append(self, owner: int, one, length: int,
               total_tokens: int) -> int:
        """Admit ``owner`` into a NEW device row from its one-row
        prefill caches; returns the row index."""
        assert len(self.rows) < self.num_slots
        self.allocator.admit(owner, self._resident(length),
                             self._resident(total_tokens))
        if self.caches is None or not self.rows:
            self.caches = _first_row(one, jnp.int32(length))
        else:
            self.caches = _append_row(self.caches, one,
                                      jnp.int32(length))
        self.rows.append(owner)
        self.lengths.append(length)
        return len(self.rows) - 1

    def refill(self, row: int, owner: int, one, length: int,
               total_tokens: int) -> None:
        """Admit ``owner`` into a released row in place (continuous
        batching's steady-state: retire + refill without resizing)."""
        assert self.rows[row] is None, "refill requires a released row"
        self.allocator.admit(owner, self._resident(length),
                             self._resident(total_tokens))
        self.caches = _write_row(self.caches, one, jnp.int32(row),
                                 jnp.int32(length))
        self.rows[row] = owner
        self.lengths[row] = length

    # -- retirement ----------------------------------------------------
    def release(self, row: int) -> None:
        """Free the row's pages (request finished).  The row must then
        be ``refill``ed or ``shrink``ed before the next decode."""
        self.allocator.release(self.rows[row])
        self.rows[row] = None

    def shrink(self, row: int) -> None:
        """Drop a released row from the decode batch (swap-with-last)."""
        assert self.rows[row] is None
        last = len(self.rows) - 1
        if last == 0:
            self.caches = None
        else:
            self.caches = _swap_shrink(self.caches, jnp.int32(row))
            self.rows[row] = self.rows[last]
            self.lengths[row] = self.lengths[last]
        self.rows.pop()
        self.lengths.pop()

    # -- decode bookkeeping --------------------------------------------
    def advance(self) -> None:
        """Mirror one decode step: every resident row appended one
        token (the device-side ``idx`` vector advanced inside the
        decode graph); grow page backing across boundaries."""
        for i, owner in enumerate(self.rows):
            assert owner is not None, "decode ran with a released row"
            self.lengths[i] += 1
            self.allocator.grow(owner, self._resident(self.lengths[i]))
