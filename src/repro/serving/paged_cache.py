"""Paged KV cache: free-list page allocator with refcounts +
copy-on-write prefix sharing, and the two device-cache placements it
governs (docs/paged-attention.md, docs/continuous-batching.md).

Three layers, deliberately separate:

``PageAllocator`` (host-side bookkeeping)
    A vLLM-style allocator over a pool of fixed-size pages
    (``page_size`` tokens each).  Pages are handed out from a free
    list and carry a REFCOUNT: a physical page may back the same
    logical page of several requests at once (prefix sharing).
    Admission reserves each request's worst-case PRIVATE page count
    (total pages minus the shared ones, plus at most one
    copy-on-write slack page) so decode can never run out mid-request
    — there is no preemption in this engine, so reservation-based
    admission is the no-corruption guarantee.  Private pages are
    allocated lazily as the sequence actually grows; on release every
    page is unreferenced, and a refcount-0 page either returns to the
    free list or — if it is registered in the prefix-hash map — parks
    in an LRU "evictable" set: still addressable by future prefix
    hits, reclaimed (hash entries dropped) only when the free list
    runs dry.  ``free_pages`` counts both, because both are
    allocatable.

    Refcount/CoW state machine of one physical page:

      free ──alloc──► private (rc=1, unhashed)
      private ──register_hash──► shared-able (rc=1, hashed)
      hashed ──prefix hit (_ref)──► shared (rc≥2)
      any rc>0 ──_unref──► rc-1; at rc=0: evictable if hashed
                                             else free list
      evictable ──prefix hit (_ref)──► shared again (revived)
      evictable ──LRU evict──► free (hash entries dropped)

    A WRITE may only target a page with rc==1 that is NOT hashed;
    ``ensure_writable`` enforces this by allocating a fresh private
    page past the frontier and COPY-ON-WRITE-replacing a shared or
    hashed page (the old page is unreferenced, the block-table entry
    repointed — the device copy is the caller's job).

``PagedKVCache`` (identity placement — the PR5 layout)
    Device rows stay per-slot contiguous ``(B, KV, C, Dh)``; the block
    table is real accounting over an identity physical mapping.  Kept
    as the fallback for families the floating pool cannot serve
    (MLA latent caches, recurrent states, windowed rings) and as the
    ``REPRO_PAGED_PLACEMENT=identity`` A/B baseline.

``FloatingPageCache`` (float placement — the default)
    One GLOBAL page pool per layer, ``(P, KV, T, Dh)`` payload +
    ``(P, KV, T)`` scales, shared by every slot; per-slot state is a
    host block table restamped into the device ``idx (B,)`` /
    ``block_table (B, NP)`` leaves before every decode.  Prefill
    still runs per request into a contiguous one-row cache; its pages
    are then scattered into the pool (``_pool_insert``).  Because the
    pool payload is batch-independent, admission/retirement/refill
    are pure host-list surgery — no device row copies — and two
    requests whose block tables point at the same physical rows
    genuinely share the bytes (the prefix-caching win: shared system
    prompts are stored once and never re-prefilled).

Prefix-hash scheme (``page_keys``): page j of a prompt is keyed by a
CHAINED hash — ``h_j = hash((h_{j-1}, tokens[j*T:(j+1)*T]))`` with a
fixed root sentinel — so a key identifies the entire prefix through
page j, not just that page's tokens.  Only FULL prompt pages are ever
registered (the frontier partial page still mutates); registration is
first-writer-wins.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import cache_len
from repro.models.transformer import (
    init_paged_pools,
    map_cache_nodes,
    paged_decode_supported,
)

PAGE_SIZE = 16

_HASH_ROOT = "moss-prefix-root"


class PagedCacheError(RuntimeError):
    pass


class PageExhausted(PagedCacheError):
    """The page pool cannot cover the requested reservation —
    admission-time backpressure (the scheduler keeps the request
    queued instead of corrupting a resident slot)."""


class SlotCapacityExceeded(PagedCacheError):
    """A sequence would outgrow its slot's ring capacity C on a
    non-windowed arch — writing on would wrap the ring and silently
    clobber live positions, so this raises *before* corruption."""


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-max(n_tokens, 0) // page_size)


def page_keys(tokens, page_size: int) -> list:
    """Chained page-aligned prefix keys of a prompt: ``keys[j]``
    identifies tokens [0, (j+1)*page_size) — page content AND its
    whole prefix — so a block-table hit on key j is only possible
    when every earlier page matched too.  Only full pages get keys
    (``len(keys) == len(tokens) // page_size``)."""
    toks = np.asarray(tokens)
    keys, prev = [], _HASH_ROOT
    for j in range(len(toks) // page_size):
        chunk = tuple(int(t) for t in toks[j * page_size:
                                           (j + 1) * page_size])
        prev = hash((prev, chunk))
        keys.append(prev)
    return keys


@dataclasses.dataclass
class BlockTable:
    """One slot's logical->physical page map.  ``pages[j]`` is the
    physical page id backing tokens [j*page_size, (j+1)*page_size);
    the leading ``shared0`` entries were mapped from prefix-hash hits
    (refcounted, not owned), the rest are private.  ``reserved`` is
    the worst-case PRIVATE page count admission committed to and
    ``private`` how many of those have materialized — the allocator
    asserts ``private <= reserved`` (reservation-overrun guard)."""
    owner: int
    pages: list[int] = dataclasses.field(default_factory=list)
    reserved: int = 0
    private: int = 0
    shared0: int = 0


class PageAllocator:
    """Free-list + refcount page-pool accounting with
    reservation-based admission and prefix-hash sharing (see module
    docstring)."""

    def __init__(self, num_pages: int, page_size: int = PAGE_SIZE,
                 slot_tokens: int | None = None,
                 usage_mode: bool = False):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        # per-slot ring capacity in tokens; None = unbounded rows
        self.slot_tokens = slot_tokens
        # usage-based admission (Scheduler v2, docs/continuous-
        # batching.md): admission reserves actual usage + small
        # headroom instead of the worst case, and a request that
        # outgrows its reservation EXTENDS it page by page —
        # ``PageExhausted`` on extension is the engine's preemption
        # trigger, not corruption.  False keeps the v1 invariant:
        # outgrowing a reservation is an accounting bug.
        self.usage_mode = usage_mode
        self._free = list(range(num_pages - 1, -1, -1))
        self._refcount = [0] * num_pages
        # refcount-0 pages kept addressable for prefix hits, oldest
        # first (LRU eviction order)
        self._evictable: OrderedDict[int, None] = OrderedDict()
        self._hash_to_page: dict = {}
        self._page_hash: dict[int, object] = {}
        self._tables: dict[int, BlockTable] = {}
        # sum over residents of (reserved - private): pages promised
        # but not yet materialized — the admission headroom term
        self._outstanding = 0
        self.peak_used = 0
        # hashed refcount-0 pages reclaimed (prefix entries dropped)
        self.evictions = 0

    # -- introspection -------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Allocatable pages: the free list plus the evictable
        (refcount-0 hashed) set."""
        return len(self._free) + len(self._evictable)

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages retained only for future prefix hits."""
        return len(self._evictable)

    @property
    def committed_pages(self) -> int:
        return sum(bt.reserved for bt in self._tables.values())

    def refcount(self, page: int) -> int:
        return self._refcount[page]

    def table(self, owner: int) -> BlockTable:
        return self._tables[owner]

    def _clamp(self, n_tokens: int) -> int:
        if self.slot_tokens is None:
            return n_tokens
        return min(n_tokens, self.slot_tokens)

    def pages_needed(self, n_tokens: int) -> int:
        return pages_for(self._clamp(n_tokens), self.page_size)

    def _note_used(self) -> None:
        self.peak_used = max(self.peak_used,
                             self.num_pages - self.free_pages)

    # -- prefix hash map -----------------------------------------------
    def lookup(self, keys: list) -> list[int]:
        """Longest registered prefix run: physical pages for
        ``keys[0..k)`` where k is the first miss."""
        pages = []
        for key in keys:
            page = self._hash_to_page.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def register_hash(self, page: int, key) -> bool:
        """Publish ``page`` as the backing of prefix ``key``.
        First-writer-wins: an already-taken key or an already-hashed
        page is left alone (returns False)."""
        if key in self._hash_to_page or page in self._page_hash:
            return False
        self._hash_to_page[key] = page
        self._page_hash[page] = key
        return True

    # -- refcount plumbing ---------------------------------------------
    def _ref(self, page: int) -> None:
        if self._refcount[page] == 0:
            # revive from the evictable set (hash entry survives)
            self._evictable.pop(page)
        self._refcount[page] += 1

    def _unref(self, page: int) -> None:
        assert self._refcount[page] > 0, \
            f"double-free of page {page}"
        self._refcount[page] -= 1
        if self._refcount[page] == 0:
            if page in self._page_hash:
                self._evictable[page] = None     # newest at the end
            else:
                self._free.append(page)

    def _drop_hash(self, page: int) -> None:
        key = self._page_hash.pop(page, None)
        if key is not None:
            del self._hash_to_page[key]

    def _alloc_page(self) -> int:
        if self._free:
            page = self._free.pop()
        elif self._evictable:
            # reclaim the least-recently-parked hashed page: its
            # prefix entry dies with it
            page, _ = self._evictable.popitem(last=False)
            self._drop_hash(page)
            self.evictions += 1
        else:
            raise PageExhausted("page pool empty")
        self._refcount[page] = 1
        self._note_used()
        return page

    def _alloc_private(self, bt: BlockTable) -> int:
        if bt.private == bt.reserved:
            # usage mode: the request outgrew its usage-based
            # reservation — extend it one page IF every outstanding
            # promise (plus this one) is still coverable; otherwise
            # raise so the engine can preempt a victim and retry.
            assert self.usage_mode, \
                (f"owner {bt.owner}: private page {bt.private + 1} "
                 f"would overrun its reservation of {bt.reserved} "
                 f"(allocator leak / accounting bug)")
            if self._outstanding + 1 > self.free_pages:
                raise PageExhausted(
                    f"owner {bt.owner}: reservation extension needs 1 "
                    f"page but {self._outstanding} outstanding promises "
                    f"already cover the {self.free_pages} allocatable "
                    f"pages (preempt to proceed)")
            bt.reserved += 1
            self._outstanding += 1
        page = self._alloc_page()
        bt.private += 1
        self._outstanding -= 1
        return page

    # -- lifecycle -----------------------------------------------------
    def _reservation(self, total_tokens: int, n_shared: int,
                     cow_slack: int) -> int:
        return max(self.pages_needed(total_tokens) - n_shared, 0) \
            + cow_slack

    def _revive_cost(self, shared) -> int:
        # shared pages currently parked evictable leave the free pool
        # on admit without consuming any reservation
        return sum(1 for p in shared if self._refcount[p] == 0)

    def can_admit(self, total_tokens: int, shared=(),
                  cow_slack: int = 0) -> bool:
        """Whether a request whose lifetime resident size is
        ``total_tokens`` (of which ``len(shared)`` pages arrive via
        prefix hits) fits: every outstanding promise plus this
        request's private reservation plus the revival of its shared
        pages must be covered by allocatable pages."""
        need = self._reservation(total_tokens, len(shared), cow_slack)
        return (self._outstanding + need + self._revive_cost(shared)
                <= self.free_pages)

    def admit(self, owner: int, prompt_tokens: int, total_tokens: int,
              shared=(), cow_slack: int = 0) -> BlockTable:
        """Reserve the request's worst-case private pages, map the
        shared prefix pages (refcounted) and allocate the remaining
        prompt pages now.  Raises ``PageExhausted`` when the pool
        cannot cover the reservation."""
        assert owner not in self._tables, f"owner {owner} already resident"
        need = self._reservation(total_tokens, len(shared), cow_slack)
        if (self._outstanding + need + self._revive_cost(shared)
                > self.free_pages):
            raise PageExhausted(
                f"reservation of {need} private pages for owner "
                f"{owner} exceeds the pool ({self.free_pages} "
                f"allocatable, {self._outstanding} outstanding)")
        bt = BlockTable(owner=owner, reserved=need,
                        shared0=len(shared))
        for page in shared:
            self._ref(page)
            bt.pages.append(page)
        self._note_used()
        self._tables[owner] = bt
        self._outstanding += need
        self._grow_to(bt, self.pages_needed(prompt_tokens))
        return bt

    def grow(self, owner: int, resident_tokens: int) -> None:
        """Back ``resident_tokens`` with physical pages.  Raises
        ``SlotCapacityExceeded`` past the slot ring and
        ``PageExhausted`` if growth outruns the reservation into an
        empty pool (impossible under reservation-based admission —
        kept as the corruption guard for direct callers)."""
        if (self.slot_tokens is not None
                and resident_tokens > self.slot_tokens):
            raise SlotCapacityExceeded(
                f"owner {owner}: {resident_tokens} tokens > slot ring "
                f"capacity {self.slot_tokens} (ring wrap would clobber "
                f"live positions)")
        self._grow_to(self._tables[owner],
                      self.pages_needed(resident_tokens))

    def _grow_to(self, bt: BlockTable, n_pages: int) -> None:
        while len(bt.pages) < n_pages:
            bt.pages.append(self._alloc_private(bt))

    def ensure_writable(self, owner: int,
                        page_idx: int) -> tuple[str, int, int]:
        """Make logical page ``page_idx`` of ``owner`` safe to write:

          "fresh"  page_idx was one past the frontier — a private
                   page was allocated and appended
          "ok"     the page is private (rc==1, unhashed): in-place
                   writes are safe
          "cow"    the page was shared (rc>1) OR hash-registered: a
                   private copy was allocated and the table entry
                   repointed — the caller must device-copy
                   old -> new before the write lands

        Returns ``(kind, old_page, new_page)`` (equal except "cow").
        Hash-registered pages CoW even at rc==1: their bytes are
        advertised to future prefix hits and must stay pristine."""
        bt = self._tables[owner]
        if page_idx == len(bt.pages):
            page = self._alloc_private(bt)
            bt.pages.append(page)
            return ("fresh", page, page)
        old = bt.pages[page_idx]
        if self._refcount[old] > 1 or old in self._page_hash:
            new = self._alloc_private(bt)
            bt.pages[page_idx] = new
            self._unref(old)
            return ("cow", old, new)
        return ("ok", old, old)

    def release(self, owner: int) -> int:
        """Unreference a retired request's pages and drop its
        remaining reservation; returns the number of pages the table
        held (shared pages may stay alive under other owners)."""
        bt = self._tables.pop(owner)
        for page in bt.pages:
            self._unref(page)
        self._outstanding -= bt.reserved - bt.private
        return len(bt.pages)


# ---------------------------------------------------------------------------
# Identity-placement device-row helpers (jitted; recompiled per row
# count, which only walks 1..num_slots).  Stacked cache leaves are
# (L, B, ...) with the slot/row dim at axis 1; idx leaves are (L, B)
# vs the one-row prefill's (L,) — the one structural asymmetry the
# tree.maps key on.
# ---------------------------------------------------------------------------


def _stamp_idx(one, length):
    """One-row prefill caches arrive with idx = padded prompt length
    (the engine right-pads prompts to a compile bucket); stamp the TRUE
    length so validity masking hides the padded garbage positions."""
    return map_cache_nodes(
        one, lambda n: n._replace(idx=jnp.full(
            (n.idx.shape[0], 1), length, jnp.int32)))


@functools.partial(jax.jit, donate_argnums=(0,))
def _first_row(one, length):
    return _stamp_idx(one, length)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _append_row(big, one, length):
    one = _stamp_idx(one, length)

    def f(a, o):
        return jnp.concatenate([a, o.astype(a.dtype)], axis=1)

    return jax.tree.map(f, big, one)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _write_row(big, one, row, length):
    # after _stamp_idx every leaf of `one` is (L, 1, ...) against the
    # big tree's (L, B, ...) — idx included — so one update rule fits
    one = _stamp_idx(one, length)

    def f(a, o):
        return jax.lax.dynamic_update_slice_in_dim(
            a, o.astype(a.dtype), row, axis=1)

    return jax.tree.map(f, big, one)


# public alias: the legacy Server merges prefilled rows with the same
# helper (one source of the slot-write/idx-stamp semantics)
write_row = _write_row


@functools.partial(jax.jit, donate_argnums=(0,))
def _truncate_idx(big, lengths):
    """Restamp every (L, B) device ``idx`` leaf from the host lengths
    vector ((B,) int32) — the verify-step graph advanced idx by the
    full draft length regardless of how many drafts were accepted, so
    after a rejection the host lengths are the truth and the device
    idx must be walked back (rejected drafts' cache slots then sit
    past idx: masked by validity, overwritten by the next write)."""
    return map_cache_nodes(
        big, lambda n: n._replace(idx=jnp.broadcast_to(
            lengths[None, :].astype(jnp.int32), n.idx.shape)))


@jax.jit
def _swap_shrink(big, row):
    """Move the last row into ``row`` and drop the last row — retiring
    a finished slot from the decode batch (wasted-FLOP satellite).
    Not donated: every output leaf is one row smaller than the input,
    so the buffers could never be reused anyway."""

    def f(a):
        a = a.at[:, row].set(a[:, -1])
        return jax.lax.slice_in_dim(a, 0, a.shape[1] - 1, axis=1)

    return jax.tree.map(f, big)


class PagedKVCache:
    """Identity-placement device cache: per-slot contiguous rows +
    lengths, governed by a ``PageAllocator`` (see module docstring).
    ``rows[i]`` is the owner id (request rid) resident in device row
    i, or None for a released row awaiting refill/shrink within an
    engine step."""

    def __init__(self, cfg, max_len: int, num_slots: int,
                 page_size: int = PAGE_SIZE,
                 num_pages: int | None = None):
        self.cfg = cfg
        self.max_len = max_len
        self.num_slots = num_slots
        self.slot_tokens = cache_len(cfg, max_len)    # ring capacity C
        self.ring = self.slot_tokens < max_len        # window arch
        slot_pages = pages_for(self.slot_tokens, page_size)
        if num_pages is None:
            num_pages = num_slots * slot_pages        # fully backed
        self.allocator = PageAllocator(
            num_pages, page_size,
            # windowed rings wrap by design — growth clamps instead of
            # raising; non-windowed rows raise before corruption
            slot_tokens=None if self.ring else self.slot_tokens)
        self._ring_clamp = self.slot_tokens
        self.caches = None          # stacked device tree, rows = len(rows)
        self.rows: list[int | None] = []
        self.lengths: list[int] = []

    # -- admission -----------------------------------------------------
    def _resident(self, n_tokens: int) -> int:
        return min(n_tokens, self._ring_clamp)

    def can_admit(self, total_tokens: int) -> bool:
        """A slot (fresh row or released row awaiting refill) AND a
        page reservation are both available."""
        has_slot = len(self.rows) < self.num_slots or None in self.rows
        return has_slot and self.allocator.can_admit(
            self._resident(total_tokens))

    def append(self, owner: int, one, length: int,
               total_tokens: int) -> int:
        """Admit ``owner`` into a NEW device row from its one-row
        prefill caches; returns the row index."""
        assert len(self.rows) < self.num_slots
        self.allocator.admit(owner, self._resident(length),
                             self._resident(total_tokens))
        if self.caches is None or not self.rows:
            self.caches = _first_row(one, jnp.int32(length))
        else:
            self.caches = _append_row(self.caches, one,
                                      jnp.int32(length))
        self.rows.append(owner)
        self.lengths.append(length)
        return len(self.rows) - 1

    def refill(self, row: int, owner: int, one, length: int,
               total_tokens: int) -> None:
        """Admit ``owner`` into a released row in place (continuous
        batching's steady-state: retire + refill without resizing)."""
        assert self.rows[row] is None, "refill requires a released row"
        self.allocator.admit(owner, self._resident(length),
                             self._resident(total_tokens))
        self.caches = _write_row(self.caches, one, jnp.int32(row),
                                 jnp.int32(length))
        self.rows[row] = owner
        self.lengths[row] = length

    # -- chunked-prefill staging (admission / attach split) ------------
    def stage_admit(self, owner: int, total_tokens: int) -> None:
        """Admission only: commit the page reservation while the
        request chunk-prefills into a detached one-row cache (the
        engine's staging slot).  No device row exists yet."""
        self.allocator.admit(owner, 0, self._resident(total_tokens))

    def stage_attach(self, owner: int, one, length: int) -> int:
        """Attach only: merge the finished staging row into the decode
        batch and materialize its page accounting — the admission half
        already ran in ``stage_admit``."""
        self.allocator.grow(owner, self._resident(length))
        assert len(self.rows) < self.num_slots
        if self.caches is None or not self.rows:
            self.caches = _first_row(one, jnp.int32(length))
        else:
            self.caches = _append_row(self.caches, one,
                                      jnp.int32(length))
        self.rows.append(owner)
        self.lengths.append(length)
        return len(self.rows) - 1

    def stage_abort(self, owner: int) -> None:
        """Drop a staged (not yet attached) request's reservation."""
        self.allocator.release(owner)

    # -- retirement ----------------------------------------------------
    def release(self, row: int) -> None:
        """Free the row's pages (request finished).  The row must then
        be ``refill``ed or ``shrink``ed before the next decode."""
        self.allocator.release(self.rows[row])
        self.rows[row] = None

    def shrink(self, row: int) -> None:
        """Drop a released row from the decode batch (swap-with-last)."""
        assert self.rows[row] is None
        last = len(self.rows) - 1
        if last == 0:
            self.caches = None
        else:
            self.caches = _swap_shrink(self.caches, jnp.int32(row))
            self.rows[row] = self.rows[last]
            self.lengths[row] = self.lengths[last]
        self.rows.pop()
        self.lengths.pop()

    # -- decode bookkeeping --------------------------------------------
    def advance(self) -> None:
        """Mirror one decode step: every resident row appended one
        token (the device-side ``idx`` vector advanced inside the
        decode graph); grow page backing across boundaries."""
        for i, owner in enumerate(self.rows):
            assert owner is not None, "decode ran with a released row"
            self.lengths[i] += 1
            self.allocator.grow(owner, self._resident(self.lengths[i]))

    def commit(self, advs) -> None:
        """Mirror one VERIFY step: row i committed ``advs[i]`` tokens
        (accepted drafts + the correction token).  Unlike floating
        placement — whose idx leaves are restamped from host lengths
        every step anyway — the identity rows carry a live device idx
        that the verify graph advanced by the FULL draft length, so a
        rejection must walk it back: one donated restamp from the
        host lengths truncates every row at once."""
        assert len(advs) == len(self.rows)
        for i, owner in enumerate(self.rows):
            assert owner is not None, "verify ran with a released row"
            self.lengths[i] += int(advs[i])
            self.allocator.grow(owner, self._resident(self.lengths[i]))
        self.caches = _truncate_idx(
            self.caches, jnp.asarray(self.lengths, jnp.int32))


# ---------------------------------------------------------------------------
# Floating-placement device helpers.  The pool payload is
# batch-independent — only the idx/block_table leaves carry the slot
# dim — so these jits recompile per (page-count, batch) geometry, both
# bounded by pages_per_slot / num_slots.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("n_new",))
def _pool_insert(pool, one, pages, n_new: int):
    """Scatter the first ``n_new`` pages of a one-row prefill cache
    into physical pool rows ``pages`` ((n_new,) int32).  Payload
    leaves: pool (L, P, KV, T, ...), one (L, 1, KV, C, ...) with
    C >= n_new*T — padded-bucket garbage past the true length rides
    along and is masked by the slot depth, exactly like the identity
    rows."""
    t = pool.k.shape[3]

    def scatter(buf, row):
        r = row[:, 0, :, :n_new * t]
        r = r.reshape(r.shape[0], r.shape[1], n_new, t, *r.shape[3:])
        r = jnp.moveaxis(r, 2, 1)           # (L, n_new, KV, T, ...)
        return buf.at[:, pages].set(r.astype(buf.dtype))

    fp8 = pool.k_scale is not None
    return pool._replace(
        k=scatter(pool.k, one.k), v=scatter(pool.v, one.v),
        k_scale=scatter(pool.k_scale, one.k_scale) if fp8 else None,
        v_scale=scatter(pool.v_scale, one.v_scale) if fp8 else None)


@functools.partial(jax.jit, donate_argnums=(0,))
def _pool_copy_page(pool, src, dst):
    """Copy one physical page (all layers, payloads + scales):
    the device half of copy-on-write."""

    def cp(buf):
        return buf.at[:, dst].set(buf[:, src])

    fp8 = pool.k_scale is not None
    return pool._replace(
        k=cp(pool.k), v=cp(pool.v),
        k_scale=cp(pool.k_scale) if fp8 else None,
        v_scale=cp(pool.v_scale) if fp8 else None)


@jax.jit
def _pool_get_page(pool, src):
    """Read one physical page (all layers, payloads + scales) out of
    the pool — the swap-OUT half of preemption.  Fixed shape (one
    page), so swapping any victim size reuses one compiled gather."""
    if pool.k_scale is None:
        return pool.k[:, src], pool.v[:, src]
    return (pool.k[:, src], pool.v[:, src],
            pool.k_scale[:, src], pool.v_scale[:, src])


@functools.partial(jax.jit, donate_argnums=(0,))
def _pool_put_page(pool, data, dst):
    """Write one swapped page's payload back into the pool — the
    swap-IN half of preemption.  ``data`` is the tuple
    ``_pool_get_page`` returned (bitwise round-trip: payloads AND
    scales are copied verbatim, never re-quantized)."""

    def put(buf, d):
        return buf.at[:, dst].set(d.astype(buf.dtype))

    if pool.k_scale is None:
        return pool._replace(k=put(pool.k, data[0]),
                             v=put(pool.v, data[1]))
    return pool._replace(
        k=put(pool.k, data[0]), v=put(pool.v, data[1]),
        k_scale=put(pool.k_scale, data[2]),
        v_scale=put(pool.v_scale, data[3]))


class FloatingPageCache:
    """Floating-placement device cache: one global page pool per
    layer, host block tables restamped into the device leaves before
    every decode (see module docstring).  API-compatible with
    ``PagedKVCache`` from the engine's point of view (`rows`,
    `lengths`, `caches`, admission/retirement verbs) plus the
    float-only verbs ``admit_shared`` / ``prepare_decode`` /
    ``register_prompt``."""

    def __init__(self, cfg, max_len: int, num_slots: int,
                 page_size: int = PAGE_SIZE,
                 num_pages: int | None = None,
                 usage_mode: bool = False):
        assert paged_decode_supported(cfg, max_len, page_size), \
            (cfg.family, max_len, page_size)
        self.cfg = cfg
        self.max_len = max_len
        self.num_slots = num_slots
        self.page_size = page_size
        self.slot_tokens = cache_len(cfg, max_len)    # == max_len
        self.ring = False
        self.pages_per_slot = self.slot_tokens // page_size
        if num_pages is None:
            num_pages = num_slots * self.pages_per_slot
        self.allocator = PageAllocator(num_pages, page_size,
                                       slot_tokens=self.slot_tokens,
                                       usage_mode=usage_mode)
        self.num_pages = num_pages
        self.cow_copies = 0
        self.rows: list[int | None] = []
        self.lengths: list[int] = []
        self.caches = None
        # pools are allocated once up front; `caches` is None while no
        # request is resident (the engine's drained-state contract) and
        # the pool tree parks here in between
        self._stash = init_paged_pools(cfg, max_len, num_pages,
                                       page_size)

    # -- admission -----------------------------------------------------
    def _resident(self, n_tokens: int) -> int:
        return min(n_tokens, self.slot_tokens)

    def can_admit(self, total_tokens: int, shared=(),
                  cow_slack: int = 0) -> bool:
        has_slot = len(self.rows) < self.num_slots or None in self.rows
        return has_slot and self.allocator.can_admit(
            self._resident(total_tokens), shared=shared,
            cow_slack=cow_slack)

    def _wake(self):
        if self.caches is None:
            self.caches, self._stash = self._stash, None

    def _insert(self, owner: int, one) -> None:
        """Scatter a cold prefill's pages into the pool."""
        bt = self.allocator.table(owner)
        pages = jnp.asarray(bt.pages, jnp.int32)
        self._wake()
        self.caches = {
            name: _pool_insert(seg, one[name], pages, len(bt.pages))
            if seg is not None else None
            for name, seg in self.caches.items()}

    def append(self, owner: int, one, length: int,
               total_tokens: int) -> int:
        """Admit a COLD request (prefilled one-row caches) into the
        pool; the batch position is just the next host-list slot —
        the pool payload has no row dim to grow."""
        assert len(self.rows) < self.num_slots
        self.allocator.admit(owner, length,
                             self._resident(total_tokens))
        self._insert(owner, one)
        self.rows.append(owner)
        self.lengths.append(length)
        return len(self.rows) - 1

    def refill(self, row: int, owner: int, one, length: int,
               total_tokens: int) -> None:
        assert self.rows[row] is None, "refill requires a released row"
        self.allocator.admit(owner, length,
                             self._resident(total_tokens))
        self._insert(owner, one)
        self.rows[row] = owner
        self.lengths[row] = length

    # -- chunked-prefill staging (admission / attach split) ------------
    def stage_admit(self, owner: int, total_tokens: int, shared=(),
                    cow_slack: int = 0) -> None:
        """Admission only: commit the reservation and map any
        prefix-hit ``shared`` pages (refcounted) while the request
        chunk-prefills its unshared suffix straight into the pool.
        No batch row exists yet — ``stage_stamp`` exposes the staging
        request's pages to the (1, chunk) step instead."""
        self.allocator.admit(owner, 0, self._resident(total_tokens),
                             shared=shared, cow_slack=cow_slack)

    def stage_ensure(self, owner: int, lo: int, hi: int) -> None:
        """Make every page that prompt positions [lo, hi) touch
        writable before a chunk step: fresh pages past the frontier, a
        copy-on-write only for the full-hit case (the chunk's first
        page is shared/hashed).  May raise ``PageExhausted`` in usage
        mode — the engine's preemption trigger."""
        t = self.page_size
        for j in range(lo // t, (hi - 1) // t + 1):
            kind, src, dst = self.allocator.ensure_writable(owner, j)
            if kind == "cow":
                self.cow_copies += 1
                self._wake()
                s, d = jnp.int32(src), jnp.int32(dst)
                self.caches = {
                    name: _pool_copy_page(seg, s, d)
                    if seg is not None else None
                    for name, seg in self.caches.items()}

    def stage_stamp(self, owner: int, depth: int) -> None:
        """Stamp the device idx/block-table leaves to ONE staging row
        ((L, 1) / (L, 1, NP)) so a (1, chunk) step writes ``owner``'s
        pages starting at ``depth``.  Unassigned table entries point
        at the trash row — a chunk's padded tail garbage lands there,
        never in another request's page."""
        self._wake()
        pages = self.allocator.table(owner).pages
        bt = np.full((1, self.pages_per_slot), self.num_pages,
                     np.int32)
        bt[0, :len(pages)] = pages
        idx = np.full((1,), depth, np.int32)

        def stamp(node):
            n_l = node.idx.shape[0]
            return node._replace(
                idx=jnp.asarray(np.broadcast_to(idx, (n_l, 1)).copy()),
                block_table=jnp.asarray(
                    np.broadcast_to(bt, (n_l, 1,
                                         self.pages_per_slot)).copy()))

        self.caches = {name: map_cache_nodes(seg, stamp)
                       if seg is not None else None
                       for name, seg in self.caches.items()}

    def stage_attach(self, owner: int, depth: int) -> int:
        """Attach only: join the decode batch at ``depth``.  Pure
        host-list surgery — the pages are already written and the
        idx/block-table leaves are restamped before the next decode."""
        assert len(self.rows) < self.num_slots
        self.rows.append(owner)
        self.lengths.append(depth)
        return len(self.rows) - 1

    def stage_abort(self, owner: int) -> None:
        """Drop a staged (not yet attached) request's pages +
        reservation."""
        self.allocator.release(owner)

    # -- preemption (swap-to-host) -------------------------------------
    def swap_out(self, row: int) -> dict:
        """Preempt: copy the row's resident pages (payloads AND
        scales, all layers — bitwise, never re-quantized) to a
        host-side store, release them and drop the row from the
        decode batch.  Returns the bundle ``swap_in`` consumes.
        Shared prefix pages are copied too — on swap-in every page
        comes back private (the dedup is lost; the honest cost of a
        preemption)."""
        owner = self.rows[row]
        depth = self.lengths[row]
        # only the pages covering [0, depth): an already-ensured but
        # still-unwritten frontier page holds nothing worth saving,
        # and swap_in re-admits at exactly pages_for(depth)
        n_live = pages_for(depth, self.page_size)
        pages = list(self.allocator.table(owner).pages)[:n_live]
        store = []
        for p in pages:
            src = jnp.int32(p)
            store.append({
                name: jax.device_get(_pool_get_page(seg, src))
                if seg is not None else None
                for name, seg in self.caches.items()})
        self.allocator.release(owner)
        self.rows[row] = None
        self.shrink(row)
        return {"owner": owner, "depth": depth, "pages": store}

    def swap_in(self, bundle: dict, total_tokens: int) -> int:
        """Re-admit a preempted request: allocate fresh (private)
        pages for its recorded depth, write the swapped payload back
        verbatim and rejoin the decode batch at that depth.  Raises
        ``PageExhausted`` when it doesn't fit yet (stays parked)."""
        owner, depth = bundle["owner"], bundle["depth"]
        assert len(self.rows) < self.num_slots
        self.allocator.admit(owner, depth,
                             self._resident(total_tokens))
        bt = self.allocator.table(owner)
        assert len(bt.pages) == len(bundle["pages"])
        self._wake()
        for p, per_seg in zip(bt.pages, bundle["pages"]):
            dst = jnp.int32(p)
            self.caches = {
                name: _pool_put_page(
                    seg, tuple(jnp.asarray(a) for a in per_seg[name]),
                    dst)
                if seg is not None else None
                for name, seg in self.caches.items()}
        self.rows.append(owner)
        self.lengths.append(depth)
        return len(self.rows) - 1

    def register_prompt(self, owner: int, keys: list) -> int:
        """Publish the owner's FULL prompt pages in the prefix-hash
        map (first-writer-wins); returns how many registered."""
        bt = self.allocator.table(owner)
        n = 0
        for j, key in enumerate(keys):
            if j < len(bt.pages):
                n += bool(self.allocator.register_hash(bt.pages[j],
                                                       key))
        return n

    # -- retirement ----------------------------------------------------
    def release(self, row: int) -> None:
        self.allocator.release(self.rows[row])
        self.rows[row] = None

    def shrink(self, row: int) -> None:
        """Drop a released row from the decode batch.  Pure host-list
        surgery (swap-with-last): the pool payload is batch-
        independent and the idx/block-table leaves are restamped
        before the next decode anyway."""
        assert self.rows[row] is None
        last = len(self.rows) - 1
        if last == 0:
            self._stash, self.caches = self.caches, None
        else:
            self.rows[row] = self.rows[last]
            self.lengths[row] = self.lengths[last]
        self.rows.pop()
        self.lengths.pop()

    # -- decode bookkeeping --------------------------------------------
    def prepare_decode(self, write_tokens: int = 1) -> None:
        """Pre-step barrier: make every row's write-target pages
        private (fresh past the frontier, copy-on-write out of shared
        or hash-registered pages) and restamp the device idx /
        block-table leaves from host state.  MUST run before each
        decode step — the step's in-graph append assumes its target
        pages are exclusively owned.  ``write_tokens`` > 1 (a
        speculative verify step, docs/speculative-decoding.md) ensures
        every page positions [lengths[i], lengths[i]+write_tokens)
        touch; the sequential page walk keeps the fresh-append
        invariant (``page_idx == len(bt.pages)``) when the window
        spans several new pages."""
        t = self.page_size
        for i, owner in enumerate(self.rows):
            assert owner is not None, "decode ran with a released row"
            lo = self.lengths[i]
            hi = lo + write_tokens
            for j in range(lo // t, (hi - 1) // t + 1):
                kind, src, dst = self.allocator.ensure_writable(
                    owner, j)
                if kind == "cow":
                    self.cow_copies += 1
                    s, d = jnp.int32(src), jnp.int32(dst)
                    self.caches = {
                        name: _pool_copy_page(seg, s, d)
                        if seg is not None else None
                        for name, seg in self.caches.items()}
        self._restamp()

    def _restamp(self) -> None:
        """Rebuild the (B,)-shaped idx and (B, NP)-shaped block-table
        leaves (with the stacked layers axis in front) from the host
        rows/lengths/tables.  Unassigned block-table tail entries
        point at the TRASH physical row (index ``num_pages`` — the
        extra row ``init_paged_pools`` allocates): decode masks them
        anyway (slot >= n_valid), and a chunked-prefill step's padded
        tail positions scatter their garbage there instead of into a
        live page."""
        b = len(self.rows)
        idx = np.asarray(self.lengths, np.int32)
        bt = np.full((b, self.pages_per_slot), self.num_pages,
                     np.int32)
        for i, owner in enumerate(self.rows):
            pages = self.allocator.table(owner).pages
            bt[i, :len(pages)] = pages

        def stamp(node):
            n_l = node.idx.shape[0]
            return node._replace(
                idx=jnp.asarray(np.broadcast_to(idx, (n_l, b)).copy()),
                block_table=jnp.asarray(
                    np.broadcast_to(bt, (n_l, b,
                                         self.pages_per_slot)).copy()))

        self.caches = {name: map_cache_nodes(seg, stamp)
                       if seg is not None else None
                       for name, seg in self.caches.items()}

    def advance(self) -> None:
        """Mirror one decode step: every resident row appended one
        token.  Page backing was already ensured by
        ``prepare_decode`` — only the host lengths move here."""
        for i, owner in enumerate(self.rows):
            assert owner is not None, "decode ran with a released row"
            self.lengths[i] += 1

    def commit(self, advs) -> None:
        """Mirror one VERIFY step: row i committed ``advs[i]`` tokens
        (accepted drafts + the correction token).  Only the host
        lengths move — truncation of rejected drafts is free under
        floating placement because the idx/block-table leaves are
        restamped from these lengths before the next step
        (``prepare_decode``), so the garbage the verify write left
        past the committed frontier sits masked (slot >= n_valid)
        until the next write overwrites it.  Pre-ensured frontier
        pages past the commit stay in the block table as the next
        step's (private, writable) targets."""
        assert len(advs) == len(self.rows)
        for i, owner in enumerate(self.rows):
            assert owner is not None, "verify ran with a released row"
            self.lengths[i] += int(advs[i])
