"""Request admission + slot lifecycle for the paged serving engine
(docs/continuous-batching.md).

Host-side and model-free by design: the scheduler owns the FIFO
queue, request state transitions (QUEUED -> RUNNING [-> PREEMPTED ->
RUNNING] -> FINISHED), stop conditions (EOS token / ``max_new``
budget), per-request latency metrics (TTFT = submit -> first token,
TPOT = mean inter-token gap after the first) and the SLO policy knobs
built on them: the per-step chunked-prefill budget and preemption
victim choice are decided here, against ``SLOTargets``, from the
latencies the scheduler already measures.  The engine asks *whether*
the head of the queue fits (``PageAllocator.can_admit`` —
page-exhaustion backpressure keeps it queued, head-of-line FIFO: a
large stuck request is not overtaken), *how many* prompt chunks to
interleave this step (``chunk_budget``) and *whom* to swap out when
the pool runs dry (``pick_victim``), and tells the scheduler *what
happened* (``on_token``); everything jax-shaped lives in ``engine``/
``paged_cache``.  That split keeps refill order, retirement,
backpressure and the SLO policies unit-testable without building a
model.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request.  ``out`` accumulates generated token
    ids (the first is produced by prefill); timestamps feed the
    TTFT/TPOT metrics.  ``arrival_time`` (seconds after the trace
    epoch) makes ``Engine.run`` model an open-loop arrival process:
    the request is submitted — and its TTFT clock started — only once
    that offset has elapsed, instead of submit-all-at-once."""

    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    eos_id: int | None = None
    arrival_time: float | None = None
    out: list = dataclasses.field(default_factory=list)
    state: RequestState = RequestState.QUEUED
    t_submit: float | None = None
    t_first: float | None = None
    t_last: float | None = None
    # stamped at admission by the engine's prefix-cache plan
    # (docs/paged-attention.md): physical pages mapped from prefix-
    # hash hits, and prompt tokens whose prefill was skipped (served
    # from the shared pages; the unshared suffix chunk-prefills at an
    # offset)
    prefix_pages: int = 0
    prefill_skipped: int = 0

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def done(self) -> bool:
        return self.state is RequestState.FINISHED

    @property
    def ttft(self) -> float | None:
        """Time to first token (s): submit -> first generated token."""
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def tpot(self) -> float | None:
        """Mean time per output token (s) after the first."""
        if self.t_first is None or self.t_last is None or len(self.out) < 2:
            return None
        return (self.t_last - self.t_first) / (len(self.out) - 1)


@dataclasses.dataclass(frozen=True)
class SLOTargets:
    """Latency service-level objectives the v2 policies steer against
    (docs/continuous-batching.md).  Defaults are loose smoke-scale
    values; benchmarks/launchers set real ones."""

    ttft_s: float = 1.0          # target time-to-first-token
    tpot_s: float = 0.1          # target per-output-token gap


def hit_stop(req: Request, token: int) -> bool:
    """THE stop rule (one source of truth — the paged scheduler and
    the legacy Server both consult it): EOS token, or the ``max_new``
    budget spent by the token just appended to ``req.out``."""
    return ((req.eos_id is not None and int(token) == req.eos_id)
            or len(req.out) >= req.max_new)


class Scheduler:
    """FIFO admission + retirement bookkeeping + SLO policy (see
    module docstring).  ``clock`` is injectable for deterministic unit
    tests."""

    def __init__(self, clock=time.monotonic, slo: SLOTargets | None = None):
        self.clock = clock
        self.slo = slo or SLOTargets()
        self.queue: deque[Request] = deque()
        self.all: list[Request] = []
        # speculative-decode accept-rate EMA (docs/speculative-
        # decoding.md): starts optimistic so the first steps probe the
        # full draft length, then tracks the trace
        self.accept_rate: float = 1.0
        self.verify_steps: int = 0
        self.drafted: int = 0
        self.accepted: int = 0

    def submit(self, requests) -> None:
        now = self.clock()
        for req in requests:
            assert req.max_new >= 1, "a request must generate >= 1 token"
            req.state = RequestState.QUEUED
            req.t_submit = now
            self.queue.append(req)
            self.all.append(req)

    def peek(self) -> Request | None:
        """Head of the FIFO queue (next admission candidate), or None."""
        return self.queue[0] if self.queue else None

    def pop(self) -> Request:
        """Commit the head to a slot (engine prefills it next)."""
        req = self.queue.popleft()
        req.state = RequestState.RUNNING
        return req

    def on_token(self, req: Request, token: int) -> bool:
        """Record one generated token; flips the request to FINISHED on
        EOS or when the ``max_new`` budget is spent.  Returns done."""
        now = self.clock()
        req.out.append(int(token))
        if req.t_first is None:
            req.t_first = now
        req.t_last = now
        if hit_stop(req, token):
            req.state = RequestState.FINISHED
            self._observe_finished(req)
        return req.done

    def _observe_finished(self, req: Request) -> None:
        """One-shot per-request latency histogram observations
        (repro.obs.metrics) — at FINISH, so repeated ``summary()``
        calls never double count."""
        from repro.obs.metrics import LATENCY_BUCKETS_S, get_registry

        reg = get_registry()
        if req.ttft is not None:
            reg.histogram("sched_ttft_seconds",
                          buckets=LATENCY_BUCKETS_S,
                          help="per-request time to first token"
                          ).observe(req.ttft)
        if req.tpot is not None:
            reg.histogram("sched_tpot_seconds",
                          buckets=LATENCY_BUCKETS_S,
                          help="per-request mean time per output token"
                          ).observe(req.tpot)

    def on_verify(self, proposed: int, accepted: int) -> None:
        """Record one speculative verify step: ``proposed`` draft
        tokens were gambled on across the batch, ``accepted`` of them
        matched the model's own argmaxes.  Updates the accept-rate EMA
        (0.8·prev + 0.2·step — slow enough to ride out one adversarial
        window, fast enough to follow a phase change in the trace)."""
        self.verify_steps += 1
        self.drafted += int(proposed)
        self.accepted += int(accepted)
        if proposed > 0:
            self.accept_rate = (0.8 * self.accept_rate
                                + 0.2 * accepted / proposed)

    def draft_len(self, k_max: int) -> int:
        """Accept-rate-aware draft length for the next verify step:
        scale the configured maximum by the EMA, floored at 2 — a
        verify step below 2 proposes nothing, so the EMA would freeze
        at its low-water mark and never recover.  (The engine may
        still clamp to 1 for capacity/budget reasons; that bypasses
        this policy, not the EMA.)"""
        if k_max <= 2:
            return max(1, k_max)
        return max(2, min(k_max, round(k_max * self.accept_rate)))

    # -- SLO policy ----------------------------------------------------
    def chunk_budget(self) -> int:
        """How many chunked-prefill steps the engine may interleave
        before the next decode step.  Deterministic and model-free:
        shrink to 1 when any running request's observed TPOT already
        exceeds its target (prefill chunks stall decode); boost when
        the queue head's wait approaches the TTFT target (its first
        token needs the whole prompt prefilled).  TTFT pressure wins
        ties — under heavy traffic the queue is where SLOs die."""
        budget = 2
        running = [r for r in self.all
                   if r.state is RequestState.RUNNING]
        tpots = [r.tpot for r in running if r.tpot is not None]
        if tpots and max(tpots) > self.slo.tpot_s:
            budget = 1
        head = self.queue[0] if self.queue else None
        if head is not None and head.t_submit is not None:
            if self.clock() - head.t_submit > 0.5 * self.slo.ttft_s:
                budget = max(budget, 4)
        return budget

    def pick_victim(self, candidates) -> Request | None:
        """Preemption victim among decode-resident requests: the one
        with the most TPOT headroom (its SLO tolerates a swap stall
        best); ties break LIFO (latest submit — the least sunk decode
        work is parked).  Deterministic given the candidates."""
        if not candidates:
            return None

        def key(r: Request):
            tpot = r.tpot
            headroom = (self.slo.tpot_s - tpot if tpot is not None
                        else self.slo.tpot_s)
            return (headroom, r.t_submit or 0.0)

        return max(candidates, key=key)

    # -- metrics -------------------------------------------------------
    def summary(self) -> dict:
        """Aggregate serving metrics over every finished request.
        p50/p99 percentiles ride alongside the means — heavy-traffic
        scheduling is judged on tails, not averages.

        Undefined aggregates (no finished requests, no drafted tokens)
        are ``None``, never NaN: the dict must stay valid JSON through
        ``json.dump`` / the metrics registry (docs/observability.md).
        The registry mirror lives in ``repro.obs.metrics`` under
        ``sched_*`` gauges/counters."""
        done = [r for r in self.all if r.done]
        toks = sum(len(r.out) for r in done)
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [r.tpot for r in done if r.tpot is not None]
        span = (max((r.t_last for r in done), default=0.0)
                - min((r.t_submit for r in done), default=0.0))

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else None

        s = {
            "requests": len(done),
            "tokens": toks,
            "tok_per_s": toks / span if span > 0 else None,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "mean_tpot_s": float(np.mean(tpots)) if tpots else None,
            "p50_ttft_s": pct(ttfts, 50),
            "p99_ttft_s": pct(ttfts, 99),
            "p50_tpot_s": pct(tpots, 50),
            "p99_tpot_s": pct(tpots, 99),
            "prefix_hit_requests": sum(r.prefix_pages > 0 for r in done),
            "prefill_tokens_skipped": sum(r.prefill_skipped
                                          for r in done),
            "spec_verify_steps": self.verify_steps,
            "spec_drafted": self.drafted,
            "spec_accepted": self.accepted,
            "spec_accept_rate": (self.accepted / self.drafted
                                 if self.drafted else None),
        }
        self._publish(s)
        return s

    def _publish(self, s: dict) -> None:
        from repro.obs.metrics import get_registry

        reg = get_registry()
        reg.counter("sched_requests_finished_total").set_total(
            float(s["requests"]))
        reg.counter("sched_tokens_generated_total").set_total(
            float(s["tokens"]))
        reg.counter("sched_spec_drafted_total").set_total(
            float(s["spec_drafted"]))
        reg.counter("sched_spec_accepted_total").set_total(
            float(s["spec_accepted"]))
        for key in ("tok_per_s", "mean_ttft_s", "mean_tpot_s",
                    "p50_ttft_s", "p99_ttft_s", "p50_tpot_s",
                    "p99_tpot_s", "spec_accept_rate"):
            if s[key] is not None:
                reg.gauge(f"sched_{key}").set(s[key])
