"""Jitted step functions: train_step (fwd+bwd+AdamW+automatic scaling),
prefill_step, decode_step — plus TrainState plumbing.

The MOSS integration points:
  1. before the forward, predicted per-tensor weight scales are computed
     from ``ScaleState`` (no max-reductions — paper Eq. 10);
  2. all linear GEMMs run the two-level-MX custom-vjp path;
  3. after the optimizer update, scale states advance one step, with a
     real max-reduction only on the lax.cond refresh branch;
  4. optional FP8-compressed gradient all-reduce (paper Table 5).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import QuantConfig, fp8_max, TINY
from repro.core.linear import QT
from repro.distributed import compression
from repro.models.layers import quant_mask_tree, wrap_qt, wrap_qt_nojit
from repro.models.transformer import ce_loss, forward, init_caches, model_defs
from repro.optim.adamw import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
)
from repro.optim.schedule import cosine_with_warmup


class TrainState(NamedTuple):
    params: Any               # f32 master weights
    opt: Any                  # OptState tree
    scale_s0: Any             # per-leaf predicted-scale base (f32)
    scale_t: Any              # per-leaf steps-since-refresh (i32)
    comm_residual: Any        # fp8-allreduce error feedback (or None)
    step: jax.Array           # i32


class TrainHParams(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 2000
    total_steps: int = 100_000
    grad_clip: float = 1.0
    aux_coef: float = 0.01
    microbatches: int = 1     # gradient accumulation (activation memory)
    adamw: AdamWConfig = AdamWConfig()


def _scale_dims(defs):
    """Leading dims that get independent fp8 scales: stacked layer dim
    (+ expert dim).  Derived from PDef logical names."""
    from repro.models.layers import PDef

    def dims(d: PDef):
        n = 0
        for name in d.logical:
            if name in ("layers", "experts"):
                n += 1
            else:
                break
        return n

    return jax.tree.map(dims, defs, is_leaf=lambda x: isinstance(x, PDef))


def init_scales(defs, params, qcfg: QuantConfig):
    """s0 per (layer, expert) slice: amax over the non-stacked dims."""
    sdims = _scale_dims(defs)

    def init(w, nd):
        axes = tuple(range(nd, w.ndim))
        amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axes)
        return jnp.maximum(amax, TINY) / fp8_max(qcfg.fwd_format)

    s0 = jax.tree.map(init, params, sdims)
    t = jax.tree.map(lambda w: jnp.zeros((), jnp.int32), params)
    return s0, t


def predicted_scales(s0, t, lr, qcfg: QuantConfig):
    def pred(s, ts):
        return s + lr * ts.astype(jnp.float32) / fp8_max(qcfg.fwd_format)
    return jax.tree.map(pred, s0, t)


def advance_scales(defs, s0, t, params, qcfg: QuantConfig):
    """One step forward; lax.cond refresh at the interval (the untaken
    branch reads no weight bytes — the paper's Table 1 saving)."""
    sdims = _scale_dims(defs)

    def adv(s, ts, w, nd):
        ts_next = ts + 1

        def refresh(_):
            axes = tuple(range(nd, w.ndim))
            amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axes)
            return (jnp.maximum(amax, TINY) / fp8_max(qcfg.fwd_format),
                    jnp.zeros((), jnp.int32))

        def keep(_):
            return (s, ts_next)

        if qcfg.weight_scaling in ("jit", "delayed"):
            return refresh(None)
        return jax.lax.cond(ts_next >= qcfg.rescale_interval,
                            refresh, keep, operand=None)

    out = jax.tree.map(adv, s0, t, params, sdims)
    new_s0 = jax.tree.map(lambda o: o[0], out,
                          is_leaf=lambda o: isinstance(o, tuple))
    new_t = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    return new_s0, new_t


def init_train_state(cfg, hp: TrainHParams, key, params=None):
    from repro.models.layers import init_tree

    defs = model_defs(cfg)
    if params is None:
        params = init_tree(defs, key)
    opt = init_opt_state(params)
    qcfg = cfg.quant
    s0, t = init_scales(defs, params, qcfg)
    res = (compression.init_residuals(params)
           if qcfg.grad_comm_fp8 else None)
    return TrainState(params=params, opt=opt, scale_s0=s0, scale_t=t,
                      comm_residual=res, step=jnp.zeros((), jnp.int32))


def make_train_step(cfg, hp: TrainHParams, mesh=None):
    """Builds the jittable train step for arch ``cfg``."""
    defs = model_defs(cfg)
    mask = quant_mask_tree(defs)
    qcfg = cfg.quant

    def train_step(state: TrainState, batch: dict):
        lr = cosine_with_warmup(state.step, peak_lr=hp.peak_lr,
                                warmup_steps=hp.warmup_steps,
                                total_steps=hp.total_steps)

        if qcfg.quantized and qcfg.weight_scaling == "auto":
            scales = predicted_scales(state.scale_s0, state.scale_t, lr,
                                      qcfg)
        else:
            scales = jax.tree.map(lambda w: None, state.params)

        def loss_fn(params, mb):
            if qcfg.quantized and qcfg.weight_scaling == "auto":
                qp = wrap_qt(params, scales, mask)
            else:
                qp = wrap_qt_nojit(params, mask)
            logits, _, aux = forward(cfg, qcfg, qp, mb, mode="train")
            loss = ce_loss(cfg, logits, mb["labels"], mb.get("mask"))
            return loss + hp.aux_coef * aux, (loss, aux)

        n_mb = hp.microbatches
        if n_mb <= 1:
            (_, (loss, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            # gradient accumulation: scan over microbatches (bounds the
            # per-layer activation carry at B/n_mb)
            mbs = jax.tree.map(
                lambda x: x.reshape(n_mb, x.shape[0] // n_mb,
                                    *x.shape[1:]), batch)

            def acc_step(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (_, (l, a)), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, loss_acc + l, aux_acc + a), None

            g0 = jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32),
                              state.params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            loss, aux = loss / n_mb, aux / n_mb

        if mesh is not None:
            # constrain gradients to the parameter sharding so GSPMD
            # emits reduce-scatters instead of full all-reduces (§Perf)
            from repro.distributed.sharding import resolve_spec
            from repro.models.layers import PDef

            def _gspec(d):
                return jax.sharding.NamedSharding(
                    mesh, resolve_spec(d.logical, mesh, d.shape))

            gspecs = jax.tree.map(_gspec, defs,
                                  is_leaf=lambda x: isinstance(x, PDef))
            grads = jax.tree.map(
                jax.lax.with_sharding_constraint, grads, gspecs)

        if qcfg.grad_comm_fp8 and mesh is not None:
            grads, new_res = compression.fp8_allreduce_grads(
                grads, state.comm_residual, mesh)
        else:
            new_res = state.comm_residual

        grads, gnorm = clip_by_global_norm(grads, hp.grad_clip)
        new_params, new_opt = adamw_update(hp.adamw, state.params, grads,
                                           state.opt, state.step, lr)
        if qcfg.quantized:
            new_s0, new_t = advance_scales(defs, state.scale_s0,
                                           state.scale_t, new_params, qcfg)
        else:
            new_s0, new_t = state.scale_s0, state.scale_t

        metrics = {"loss": loss, "aux": aux, "lr": lr, "grad_norm": gnorm}
        return TrainState(params=new_params, opt=new_opt, scale_s0=new_s0,
                          scale_t=new_t, comm_residual=new_res,
                          step=state.step + 1), metrics

    return train_step


def make_eval_step(cfg):
    defs = model_defs(cfg)
    mask = quant_mask_tree(defs)
    qcfg = cfg.quant

    def eval_step(params, batch):
        qp = wrap_qt_nojit(params, mask)
        logits, _, _ = forward(cfg, qcfg, qp, batch, mode="train")
        return ce_loss(cfg, logits, batch["labels"], batch.get("mask"))

    return eval_step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def serve_weight_scales(cfg, params):
    """Per-tensor fp8 scales for a frozen serving model, computed ONCE
    at build time.  Without these, every prefill/decode step re-reduces
    ``max|W|`` for every quantized weight inside the jitted graph (the
    Table-1 traffic automatic scaling removes from training) — for
    serving the weights never change, so the scales are build-time
    constants.  Returns None in bf16 mode and for jit/delayed scaling
    recipes (whose defined semantics are the in-step reduction —
    ``_quantize_w`` only consumes supplied scales in "auto" mode)."""
    if not (cfg.quant.quantized and cfg.quant.weight_scaling == "auto"):
        return None
    return init_scales(model_defs(cfg), params, cfg.quant)[0]


def prequantize_params(cfg, params):
    """Quantize the WHOLE weight stack to fp8 payloads + scales at
    server build time — the step beyond ``serve_weight_scales``: not
    only the max-reductions but the fp8 casts themselves leave the
    decode/prefill graphs, and weight HBM traffic drops to 1
    byte/element for every quantized GEMM.

    Works for every quantized recipe (``per_tensor``, ``per_group``,
    ``moss`` — weights are per-tensor-quantized in all three; the
    per-group/micro-group machinery applies to activations, which are
    dynamic and stay quantized in-graph).  Per-(layer, expert) slices
    get independent scales, matching what the scan-over-layers forward
    quantizes one slice at a time, so serving outputs are *bitwise*
    identical to the in-graph path (tests/test_serving.py).

    Returns a ``PrequantParams`` (qweights, scales), or None in bf16
    mode.  Never-quantized leaves (norms, routers, embeddings) keep
    their raw arrays and in-graph behavior.

    Tied-embedding models additionally get a build-time fp8
    **transposed head** (``embed/head_t``, COAT-style dual layout): the
    historical tied path re-quantized the vocab-sized ``embeddingᵀ``
    inside EVERY decode step — the one remaining vocab-sized fp8 cast
    in the decode graph.  The payload is quantized with the same
    in-graph (amax) scale the tied path computed — amax is
    transpose-invariant — so serving logits stay bitwise identical
    while the cast and its reduction leave the graph
    (tests/test_serving.py tied-head parity).
    """
    from repro.core.quant import PrequantParams, prequant_weight

    qcfg = cfg.quant
    if not qcfg.quantized:
        return None
    defs = model_defs(cfg)
    sdims = _scale_dims(defs)
    mask = quant_mask_tree(defs)
    auto = qcfg.weight_scaling == "auto"
    pred = init_scales(defs, params, qcfg)[0] if auto else None

    def leaf(w, nd, m, s):
        if not m:
            return w, jnp.ones((), jnp.float32)
        # "auto" recipes quantize against the predicted (build-time
        # amax) scale like serve_weight_scales; jit/delayed recipes
        # reduce amax over the (possibly bf16-cast) slice exactly as
        # the in-graph quantizer would
        return prequant_weight(w, nd, qcfg.fwd_format,
                               scale=s if auto else None,
                               cast_bf16=qcfg.weight_cast_bf16)

    out = jax.tree.map(leaf, params, sdims, mask,
                       pred if auto else sdims)
    is_pair = lambda o: isinstance(o, tuple) and len(o) == 2
    qweights = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    scales = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    if cfg.tie_embeddings:
        # scale=None ALWAYS (even for auto recipes): the in-graph tied
        # path is QT(embᵀ, None) → jit weight scaling, and amax is
        # transpose-invariant, so this reproduces it bitwise
        q, s = prequant_weight(
            jnp.asarray(params["embed"]["embedding"]).T, 0,
            qcfg.fwd_format, scale=None,
            cast_bf16=qcfg.weight_cast_bf16)
        qweights["embed"]["head_t"] = q
        scales["embed"]["head_t"] = s
    return PrequantParams(qweights=qweights, scales=scales)


def serve_quant_mask(cfg, tree=None):
    """The serving quantization mask: ``quant_mask_tree`` patched with
    the prequant transposed tied head (``embed/head_t``) when ``tree``
    (a serving params or scales tree) carries one — the head is not a
    PDef, it exists only in prequantized serving trees."""
    mask = quant_mask_tree(model_defs(cfg))
    if (isinstance(tree, dict) and isinstance(tree.get("embed"), dict)
            and "head_t" in tree["embed"]):
        mask = {**mask, "embed": {**mask["embed"], "head_t": True}}
    return mask


def _wrap_serve(params, mask, scales, act=None):
    """QT-wrap with cached build-time scales when available.  ``params``
    may be the raw tree or ``PrequantParams.qweights`` (fp8 payloads) —
    the linear layer keys off the leaf dtype.

    ``act`` is the flat ``{site tag: ActScale}`` dict from
    ``repro.core.actscale.calibrate_act_scales``: each quantized leaf
    additionally gets its site's calibrated activation scales in the
    third QT field, flipping ``qlinear`` onto the reduction-free
    delayed forward (docs/serving.md)."""
    if act:
        from repro.core.actscale import path_tag

        tmw = jax.tree_util.tree_map_with_path
        if scales is None:
            return tmw(lambda p, w, m: QT(w, None, act.get(path_tag(p)))
                       if m else w, params, mask)
        return tmw(lambda p, w, s, m: QT(w, s, act.get(path_tag(p)))
                   if m else w, params, scales, mask)
    if scales is None:
        return wrap_qt_nojit(params, mask)
    return wrap_qt(params, scales, mask)


def _health_act(act_scales, quant_health: bool):
    """Build-time resolution of the quant-health tap (repro.obs.
    quant_health): when the flag is on and delayed activation scales
    exist, each site's ``ActScale`` is wrapped in a ``TaggedScale`` so
    ``qlinear`` can report per-site stats.  Off (the default) returns
    ``act_scales`` untouched — the step graphs are byte-identical to a
    build without this feature."""
    if quant_health and act_scales:
        from repro.obs.quant_health import tag_act_scales

        return tag_act_scales(act_scales), True
    return act_scales, False


def _forward_health(health: bool, cfg, qcfg, qp, batch, caches, mode):
    """forward() plus, when health is on, the collected per-site stats
    tree (None otherwise — and then this is exactly ``forward``)."""
    if not health:
        logits, caches, _ = forward(cfg, qcfg, qp, batch, caches,
                                    mode=mode)
        return logits, caches, None
    from repro.obs.quant_health import QH

    with QH.capture() as cap:
        logits, caches, _ = forward(cfg, qcfg, qp, batch, caches,
                                    mode=mode)
    return logits, caches, cap.tree


def make_prefill_step(cfg, max_len: int, scales=None, act_scales=None,
                      quant_health: bool = False):
    """``scales`` (from ``serve_weight_scales``) threads pre-computed
    per-tensor weight scales through; None falls back to in-step (jit)
    scaling — the training-eval behavior.

    The built step takes an optional third argument ``last`` — the
    index of the logits position to return (int32 scalar).  The
    serving engine right-pads prompts to a length bucket so prefill
    compiles once per bucket instead of once per prompt length; the
    causally-correct last-token logits then sit at the true prompt
    length - 1, not at -1 (docs/continuous-batching.md).  ``None``
    (the default) keeps the historical behavior: logits[:, -1:].

    ``act_scales`` (from ``repro.core.actscale.calibrate_act_scales``)
    swaps in-graph activation amax reductions for the calibrated
    delayed scales; None keeps just-in-time scaling.

    ``quant_health=True`` (REPRO_QUANT_HEALTH=1, engine-resolved)
    additionally returns the per-site quantization-health stats tree
    as a THIRD output — docs/observability.md."""
    mask = serve_quant_mask(cfg, scales)
    qcfg = cfg.quant
    act, health = _health_act(act_scales, quant_health)

    def prefill_step(params, batch, last=None):
        qp = _wrap_serve(params, mask, scales, act)
        b = (batch["tokens"].shape[0] if "tokens" in batch
             else batch["embeds"].shape[0])
        caches = init_caches(cfg, b, max_len)
        logits, caches, qh = _forward_health(health, cfg, qcfg, qp,
                                             batch, caches, "prefill")
        if last is None:
            logits = logits[:, -1:]
        else:
            logits = jax.lax.dynamic_slice_in_dim(logits, last, 1,
                                                  axis=1)
        if health:
            return logits, caches, qh
        return logits, caches

    return prefill_step


def make_chunk_prefill_step(cfg, scales=None, act_scales=None,
                            quant_health: bool = False):
    """Chunked-prefill step — a documented alias of
    ``make_decode_step``.

    One mixed-step graph serves both shapes: the engine feeds (B, 1)
    decode tokens and (1, C) prompt chunks through the SAME jitted
    callable; jit shape-specializes each, and the (1, C) trace takes
    decode mode's S > 1 path (``attention._chunk_attention``) — the
    chunk is written at the slot's current depth (the start position
    and per-slot RoPE offsets ride in the caches' ``idx``), attending
    the already-resident pages via the block table plus an in-chunk
    causal mask.  ONE chunk shape replaces v1's per-16-token-bucket
    prefill compiles (docs/continuous-batching.md)."""
    return make_decode_step(cfg, scales=scales, act_scales=act_scales,
                            quant_health=quant_health)


def make_decode_step(cfg, scales=None, act_scales=None,
                     quant_health: bool = False):
    mask = serve_quant_mask(cfg, scales)
    qcfg = cfg.quant
    act, health = _health_act(act_scales, quant_health)

    def decode_step(params, caches, tokens):
        """tokens: (B, 1) int32 (or embeds (B,1,d)) -> next logits."""
        qp = _wrap_serve(params, mask, scales, act)
        batch = ({"embeds": tokens} if cfg.input_mode == "embeddings"
                 and tokens.ndim == 3 else {"tokens": tokens})
        logits, caches, qh = _forward_health(health, cfg, qcfg, qp,
                                             batch, caches, "decode")
        if health:
            return logits, caches, qh
        return logits, caches

    return decode_step


def make_verify_step(cfg, scales=None, act_scales=None,
                     quant_health: bool = False):
    """Speculative verify step (docs/speculative-decoding.md).

    The built step takes ``tokens (B, k)`` = [last committed token,
    draft_1 .. draft_{k-1}] per row, writes all k positions to the
    cache and returns logits for ALL k positions in one forward —
    position j's logits are what sequential decode would emit after
    feeding tokens[:, :j+1], so greedy accept/reject against them is
    token-for-token exact.  Unlike the chunked-prefill path the
    history is attended through the fused batched-query decode kernel
    (mode="verify"): no cache-sized dequant upcasts, no quant
    reductions beyond the k-position storage writes.  The caller
    truncates per-slot lengths on rejection (the written-but-rejected
    positions are simply never covered by ``n_valid`` again)."""
    mask = serve_quant_mask(cfg, scales)
    qcfg = cfg.quant
    act, health = _health_act(act_scales, quant_health)

    def verify_step(params, caches, tokens):
        """tokens: (B, k) int32 -> ((B, k, V) logits, caches)."""
        qp = _wrap_serve(params, mask, scales, act)
        logits, caches, qh = _forward_health(health, cfg, qcfg, qp,
                                             {"tokens": tokens}, caches,
                                             "verify")
        if health:
            return logits, caches, qh
        return logits, caches

    return verify_step
