"""Delayed activation scales for serving — the paper's automatic
scaling carried to decode time (ROADMAP "Automatic scaling
everywhere").

Training already predicts *weight* scales (``core.autoscale``) so no
``max|W|`` reduction appears in the steady-state HLO, and serving
pre-quantizes weights outright (``PrequantParams``).  What remained in
the decode graph were the per-step **activation** amax reductions:
every quantized GEMM re-measured ``max|x|`` (per tensor, per COAT
group, or per MOSS micro-group) on the hot path.  FP8-LM (Peng et al.,
2023) and Graphcore's scaled-FP8 study (Perez et al., 2023) both show
delayed / statistics-based activation scaling transfers to inference
at negligible accuracy cost — a given site's activation distribution
is stable across decode steps.

This module implements that end to end:

  1. ``calibrate_act_scales`` runs ONE eager (unjitted) forward over a
     deterministic calibration prompt at ``Engine``/``Server`` build,
     recording per-site activation amax statistics at the finest
     granularity the recipe quantizes at (scalar / per-group /
     per-micro-group);
  2. each site's statistics — multiplied by a safety ``margin`` —
     become an ``ActScale``, stored in a flat ``{tag: ActScale}`` dict
     keyed by the site's params-tree path (e.g. ``"blocks/attn/wq"``)
     with leading stacked (layer[, expert]) dims, so ``lax.scan`` /
     ``vmap`` slice them exactly like the weight leaves they ride
     beside;
  3. ``repro.train.steps._wrap_serve`` attaches each site's
     ``ActScale`` as the third ``QT`` field and ``core.linear.qlinear``
     consumes it through the reduction-free ``_qmm_delayed`` forward —
     the decode jaxpr then contains **zero** quantization reductions
     (``core.introspect.count_quant_reductions``; the fp8 KV cache's 2
     per-layer storage-format reductions remain unless
     ``REPRO_KV_CACHE=bf16`` — docs/serving.md).

Out-of-range activations saturate (the quantizers' clipping cast),
bounded by the margin; ``Engine.refresh_act_scales`` re-calibrates
outside the hot jaxpr.  ``REPRO_SERVE_DELAYED_ACT=0`` is the escape
hatch back to just-in-time activation scaling, restoring the
pre-delayed graphs bitwise.

Recording rides the calibration forward through the SAME model code
serving runs: each quantized ``QT`` carries its site tag string in the
``a`` field, and ``qlinear`` reports its concrete input amax here
(``REC``) before taking the normal just-in-time path.  The forward is
python-unrolled (no scan/vmap tracers): stacked segment params are
sliced one layer at a time, and the MoE block takes its dense
every-expert path (what decode uses) with a python loop over experts.
"""

from __future__ import annotations

import contextlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import TINY, QuantConfig, e8m0_decode, e8m0_encode, fp8_max

DEFAULT_MARGIN = 1.25
CALIBRATION_TOKENS = 32
_CAL_SEED = 0xAC5


class ActScale(NamedTuple):
    """One serving site's delayed activation scale(s).

    Shapes carry the site's stacked (layer[, expert]) leading dims —
    scan/vmap slice them alongside the weight — written below for one
    slice (K = the GEMM inner dim, zero-padded to the group multiple):

      per_tensor   s: () f32 per-tensor scale           sub: None
      per_group    s: (K/group,) f32 per-group scales   sub: None
      moss         s: () f32 level-1 scale              sub: (K/micro,)
                   int8 E8M0 level-2 exponents (2^sub ∈ (0, 1])

    Every group's effective scale upper-bounds its calibration amax by
    the safety margin (MOSS's E8M0 ratios round UP); in-range decode
    activations quantize exactly as a just-in-time scale of the same
    value would, out-of-range ones saturate via the clipping cast."""

    s: jax.Array
    sub: jax.Array | None = None


class _Recorder:
    """Module-level calibration recorder: ``qlinear`` reports concrete
    per-site activation amaxes here while a calibration forward runs."""

    def __init__(self):
        self.recording = False
        self.index: tuple[int, ...] = ()
        self.stats: dict[str, dict[tuple[int, ...], np.ndarray]] = {}

    @contextlib.contextmanager
    def calibrating(self):
        self.recording, self.index, self.stats = True, (), {}
        try:
            yield self
        finally:
            self.recording = False

    @contextlib.contextmanager
    def at_index(self, idx: tuple[int, ...]):
        prev, self.index = self.index, idx
        try:
            yield
        finally:
            self.index = prev

    @contextlib.contextmanager
    def sub_index(self, i: int):
        with self.at_index(self.index + (int(i),)):
            yield

    def record(self, tag: str, x, cfg: QuantConfig) -> None:
        """Accumulate the finest-granularity amax of activation ``x``
        (the GEMM's left operand, inner dim last) for site ``tag`` at
        the current (layer[, expert]) index.  Tracers are skipped —
        only concrete calibration activations count."""
        if isinstance(x, jax.core.Tracer):
            return
        k = x.shape[-1]
        xf = jnp.abs(jnp.asarray(x, jnp.float32).reshape(-1, k))
        g = (cfg.group_size if cfg.mode == "per_group"
             else cfg.micro_group if cfg.mode == "moss" else None)
        if g is not None:
            pad = (-k) % g
            if pad:
                xf = jnp.pad(xf, ((0, 0), (0, pad)))
            amax = jnp.max(xf.reshape(xf.shape[0], -1, g), axis=(0, 2))
        else:
            amax = jnp.max(xf)
        amax = np.asarray(jax.device_get(amax))
        site = self.stats.setdefault(tag, {})
        prev = site.get(self.index)
        site[self.index] = (amax if prev is None
                            else np.maximum(prev, amax))


REC = _Recorder()


def effective_group_scales(a: ActScale, cfg: QuantConfig,
                           k: int) -> tuple[jax.Array, int]:
    """The per-quantization-group effective scale an ``ActScale``
    slice implies, for a GEMM whose inner dim is ``k`` — the
    quant-health tap's view of the calibrated range
    (docs/observability.md): a group's values clip once their
    magnitude exceeds ``scale_g · FP8_MAX``.

    Returns ``(scales (K'/g,), g)`` where ``g`` is the recipe's group
    width (``k`` itself for per_tensor — one group) and ``K'`` is
    ``k`` zero-padded up to a multiple of ``g``, matching the padding
    the delayed quantizers apply."""
    if cfg.mode == "moss":
        s1 = jnp.maximum(jnp.asarray(a.s, jnp.float32), TINY)
        ss = e8m0_decode(jnp.asarray(a.sub, jnp.int8))
        return (ss * s1).reshape(-1), cfg.micro_group
    if cfg.mode == "per_group":
        return jnp.asarray(a.s, jnp.float32).reshape(-1), cfg.group_size
    return jnp.asarray(a.s, jnp.float32).reshape(-1), k


def path_tag(path) -> str:
    """Canonical site tag for a params-tree path: keys joined by "/" —
    shared by calibration recording and serve-time wrapping, so the
    flat ``{tag: ActScale}`` dict lines up by construction (layers and
    experts are stacked array dims, not tree levels, so the full-tree
    path IS the site identity)."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _stack_site(per_idx: dict[tuple[int, ...], np.ndarray]) -> np.ndarray:
    """{(layer[, expert]) index: stat array} -> one stacked array whose
    leading dims mirror the site's stacked weight dims."""
    idxs = sorted(per_idx)
    depth = len(idxs[0])
    if depth == 0:
        return np.asarray(per_idx[()])
    dims = tuple(max(i[d] for i in idxs) + 1 for d in range(depth))
    n = 1
    for d in dims:
        n *= d
    assert len(idxs) == n, \
        f"sparse calibration grid: {len(idxs)} records for dims {dims}"
    flat = np.stack([np.asarray(per_idx[i]) for i in idxs])
    return flat.reshape(*dims, *flat.shape[1:])


def _to_scales(amax: np.ndarray, cfg: QuantConfig,
               margin: float) -> ActScale:
    """Calibrated amax statistics -> the recipe's ActScale."""
    fmax = float(fp8_max(cfg.fwd_format))
    s_fine = (np.maximum(amax, TINY) / fmax).astype(np.float32)
    if cfg.mode in ("per_tensor", "per_group"):
        return ActScale(s=jnp.asarray(margin * s_fine, jnp.float32))
    assert cfg.mode == "moss", cfg.mode
    # level-1 = margin · max_g s_g; level-2 E8M0 = ceil-encoded ratio.
    # Rounding UP means every group's effective scale ≥ margin · s_g —
    # never an underestimate; the clipping cast backstops any
    # post-calibration drift beyond the margin.
    s1 = margin * np.maximum(s_fine.max(axis=-1), TINY)
    ratio = (margin * s_fine) / s1[..., None]
    sexp = np.asarray(jax.device_get(
        e8m0_encode(jnp.asarray(ratio, jnp.float32))))
    return ActScale(s=jnp.asarray(s1, jnp.float32),
                    sub=jnp.asarray(sexp, jnp.int8))


# ---------------------------------------------------------------------------
# Calibration forward (eager, python-unrolled)
# ---------------------------------------------------------------------------


def calibration_tokens(cfg, n: int = CALIBRATION_TOKENS) -> np.ndarray:
    """Deterministic calibration prompt: fixed seed, fixed length,
    independent of engine geometry (num_slots / max_len) — every
    Engine/Server over the same weights calibrates to the same scales,
    so engine-vs-engine parity tests stay exact."""
    rng = np.random.default_rng(_CAL_SEED)
    if cfg.input_mode == "embeddings":
        return rng.standard_normal((1, n, cfg.d_model)).astype(np.float32)
    return rng.integers(0, cfg.vocab, size=(1, n)).astype(np.int32)


def _tag_wrap(params, scales, mask):
    """QT-wrap quantized leaves with their site tag riding in ``a``."""
    from .linear import QT

    tmw = jax.tree_util.tree_map_with_path
    if scales is None:
        return tmw(lambda p, w, m: QT(w, None, path_tag(p)) if m else w,
                   params, mask)
    return tmw(lambda p, w, s, m: QT(w, s, path_tag(p)) if m else w,
               params, scales, mask)


def _slice_stacked(tree, l: int):
    """Index layer ``l`` out of a stacked segment subtree, preserving
    QT tag strings (jax.tree.map would descend into them)."""
    from .linear import QT

    if isinstance(tree, QT):
        return QT(tree.w[l], None if tree.s is None else tree.s[l],
                  tree.a)
    if isinstance(tree, dict):
        return {k: _slice_stacked(v, l) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_slice_stacked(v, l) for v in tree)
    if hasattr(tree, "ndim"):
        return tree[l]
    return tree


def calibrate_act_scales(cfg, params, scales=None, *, tokens=None,
                         margin: float = DEFAULT_MARGIN) -> dict | None:
    """One eager forward over the calibration prompt -> flat
    ``{site tag: ActScale}`` dict (None for unquantized recipes).

    ``params``/``scales`` are the serving trees ``prepare_weights``
    built — fp8 weight payloads pass through ``qlinear`` exactly as in
    the jitted steps, so calibration sees the numerics decode will run.
    The forward mirrors ``transformer.forward``'s train path (identical
    quantized GEMM sites, no cache) but python-unrolls the layer scans:
    every recorded amax is a concrete value, indexed per layer (and per
    expert inside the MoE dense path)."""
    qcfg = cfg.quant
    if not qcfg.quantized:
        return None
    from repro.models.layers import apply_norm, embed_tokens, lm_head
    from repro.models.transformer import build_segments
    from repro.train.steps import serve_quant_mask

    wrapped = _tag_wrap(params, scales, serve_quant_mask(cfg, params))
    if tokens is None:
        tokens = calibration_tokens(cfg)
    with REC.calibrating():
        if cfg.input_mode == "embeddings":
            x = jnp.asarray(tokens, jnp.bfloat16)
        else:
            x = embed_tokens(cfg, wrapped["embed"],
                             jnp.asarray(tokens, jnp.int32))
        s = x.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        if cfg.pos_embedding == "sinusoidal":
            from repro.models.layers import sinusoidal_embedding

            pe = sinusoidal_embedding(positions, cfg.d_model)
            x = x + pe[None].astype(x.dtype)
        for seg in build_segments(cfg):
            p_seg = wrapped[seg.name]
            for l in range(seg.n):
                with REC.at_index((l,)):
                    x, _, _ = seg.apply(cfg, qcfg,
                                        _slice_stacked(p_seg, l), x,
                                        positions, None, "train")
        x = apply_norm(cfg, wrapped["final_norm"], x)
        lm_head(cfg, wrapped["embed"], x, qcfg)
        stats = REC.stats
    return {tag: _to_scales(_stack_site(per_idx), qcfg, margin)
            for tag, per_idx in stats.items()}
