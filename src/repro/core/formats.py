"""FP8 / microscaling format constants and helpers.

The OCP MX spec stores level-2 scales in E8M0: an 8-bit biased exponent
with no sign and no mantissa — i.e. exactly the powers of two
2^-127 .. 2^127.  We represent E8M0 values as **int8 exponents** (the
unbiased exponent) and reconstruct the scale with ``exp2``.  This is
bit-equivalent in semantics, trivially portable across backends, and
cheap inside Pallas kernels (an exp2 on the VPU / exponent-add on the
operand path).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

# Maximum representable magnitudes (OCP OFP8 spec / paper §2.1).
E4M3_MAX = 448.0
E5M2_MAX = 57344.0

# Smallest normal, used to guard log2 of zero scales.
TINY = 1e-30

# E8M0 exponent range (unbiased).  MOSS subscales live in (0, 1] so the
# used range is [-127, 0], but we keep the full format range available.
E8M0_MIN_EXP = -127
E8M0_MAX_EXP = 127

FP8Format = Literal["e4m3", "e5m2"]


def fp8_max(fmt: FP8Format) -> float:
    return E4M3_MAX if fmt == "e4m3" else E5M2_MAX


def fp8_dtype(fmt: FP8Format):
    return jnp.float8_e4m3fn if fmt == "e4m3" else jnp.float8_e5m2


def cast_fp8(x, fmt: FP8Format):
    """Saturating cast to FP8.

    XLA's convert to e4m3fn produces NaN for out-of-range inputs, so an
    explicit clip implements the saturating semantics hardware quantizers
    (and the paper) use.
    """
    m = fp8_max(fmt)
    return jnp.clip(x, -m, m).astype(fp8_dtype(fmt))


def e8m0_encode(ratio):
    """ceil(log2(ratio)) as int8 exponent; ratio expected in (0, 1].

    Matches paper Eq. (3): ``ss_i = 2^ceil(log2(s_i/s))``.  ceil (rather
    than nearest) guarantees ``s * ss_i >= s_i`` so the grouped values
    never overflow the FP8 range after scaling.  The 1e-6 guard keeps
    ulp noise in the ratio from bumping exact powers of two up one
    exponent (the saturating fp8 cast absorbs the ≤1-ulp clip risk).
    """
    r = jnp.maximum(ratio, 2.0 ** -149)   # smallest f32 subnormal: only
    e = jnp.ceil(jnp.log2(r) - 1e-6)      # guards log2(0) -> -inf
    return jnp.clip(e, E8M0_MIN_EXP, E8M0_MAX_EXP).astype(jnp.int8)


def e8m0_decode(exp):
    """int8 exponent -> power-of-two f32 scale, exact over the full
    E8M0 range.  (jnp.exp2(-127) would flush the subnormal result to 0
    on CPU; building the f32 bit pattern directly is exact: 2^-127 is
    the subnormal 0x00400000.)"""
    import jax

    e = exp.astype(jnp.int32)
    normal = (e + 127) << 23
    sub = jnp.int32(0x00400000)            # 2^-127
    bits = jnp.where(e > -127, normal, sub)
    return jax.lax.bitcast_convert_type(bits.astype(jnp.int32),
                                        jnp.float32)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization recipe for one linear layer (and globally).

    mode:
      - "bf16":       no quantization (the BF16 baseline)
      - "per_tensor": TE-style, one f32 scale per tensor
      - "per_group":  COAT-style, f32 scale per `group_size` along K
      - "moss":       two-level microscaling (level-1 f32 per tensor,
                      level-2 E8M0 per `micro_group` along K)
    weight_scaling:
      - "jit":     max-reduction every step (just-in-time)
      - "delayed": previous step's amax (history window of 1)
      - "auto":    MOSS automatic scaling (predicted, interval refresh)
    """

    mode: Literal["bf16", "per_tensor", "per_group", "moss"] = "moss"
    fwd_format: FP8Format = "e4m3"
    bwd_format: FP8Format = "e5m2"
    micro_group: int = 32          # k2 in the paper
    group_size: int = 128          # COAT per-group baseline size
    weight_scaling: Literal["jit", "delayed", "auto"] = "auto"
    rescale_interval: int = 500    # automatic-scaling refresh interval
    # fp8 gradient all-reduce compression (paper Table 5) + error feedback
    grad_comm_fp8: bool = False
    # cast master weights to bf16 before quantization: halves FSDP
    # weight all-gather bytes when GSPMD hoists the gather above the
    # fp8 cast (§Perf); one extra rounding, << the fp8 noise floor
    weight_cast_bf16: bool = False

    @property
    def quantized(self) -> bool:
        return self.mode != "bf16"


BF16_CONFIG = QuantConfig(mode="bf16")
MOSS_CONFIG = QuantConfig(mode="moss")
PER_TENSOR_CONFIG = QuantConfig(mode="per_tensor", weight_scaling="jit")
PER_GROUP_CONFIG = QuantConfig(mode="per_group", weight_scaling="jit")
