"""Quantized linear layers with custom VJP — the MOSS training integration.

``qmm(cfg, x, w, w_scale)`` computes ``x @ w`` under the configured FP8
recipe with a fully custom backward:

  forward   y  = MXFP8-GEMM(Qx, Qw) · s_x·s_w          (E4M3 operands)
  residuals fp8 Qx (+ E8M0 exponents + one f32) and fp8 Qw — this is the
            paper's 1.8× activation-memory saving: backward never needs
            the bf16 activation.
  backward  dx = MXFP8-GEMM(Qg, Qwᵀ)                    (g in E5M2)
            dW = MXFP8-GEMM(requant_M(Qx)ᵀ, Qg)         (inner dim = tokens)

Weight scales come from MOSS automatic scaling (``w_scale`` argument,
predicted by ``repro.core.autoscale``) so no max|W| reduction appears in
the steady-state HLO.

All four recipes are selectable for baseline comparisons: ``bf16``,
``per_tensor`` (TE-style), ``per_group`` (COAT-style), ``moss``.

Every quantized GEMM here (forward, dx, dW) goes through the unified
kernel dispatch (``repro.kernels.dispatch``): Pallas-native on TPU,
interpret-mode Pallas under ``REPRO_KERNELS=interpret``, pure-jnp
reference on CPU.  The MOSS forward and dx use the *fused*
quantize+GEMM kernel; dW uses the fused requant-along-tokens kernel
whose level-1 scale is pinned to the forward's s_x (it cancels inside
the kernel — kernels/mx_bwd.py).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .actscale import ActScale, REC
from .formats import QuantConfig
from .quant import (
    PerTensorQ,
    quant_mx_delayed,
    quant_per_group,
    quant_per_tensor,
)


class QT(NamedTuple):
    """A weight tensor bundled with its (possibly predicted) fp8 scale.

    ``s`` is None in bf16 mode or for never-quantized params (norms,
    routers, recurrence gates); model code unwraps ``.w`` for those.

    Serving fast path: ``w`` may arrive *already quantized* (fp8 dtype,
    from ``repro.core.quant.PrequantParams``) with ``s`` its build-time
    dequant scale — ``_quantize_w`` detects the dtype and skips the
    in-graph quantize + max-reduction entirely (docs/serving.md).

    ``a`` carries this GEMM site's *activation*-scale state on the
    delayed-activation serving path (``repro.core.actscale``):

      None               — default: activations quantize just-in-time
                           (in-graph amax), the training semantics
      ActScale           — calibrated delayed scales: ``qlinear`` takes
                           the reduction-free ``_qmm_delayed`` forward
      str (site tag)     — calibration only: ``qlinear`` records the
                           activation's amax under this tag and then
                           runs the normal just-in-time path
    """

    w: jax.Array
    s: jax.Array | None = None
    a: Any = None


def _is_fp8(w: jax.Array) -> bool:
    return w.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2)


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad ``axis`` up to a multiple of ``mult`` (zeros are exact
    under all our quantizers: amax of a zero group is clamped to TINY)."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# custom_vjp core:  (cfg static) (x, w, w_scale) -> y
#   x: (..., K)   w: (K, N)   w_scale: f32 scalar or None-like scalar
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def qmm(cfg: QuantConfig, x: jax.Array, w: jax.Array,
        w_scale: jax.Array) -> jax.Array:
    y, _ = _qmm_fwd(cfg, x, w, w_scale)
    return y


def _quantize_w(cfg: QuantConfig, w: jax.Array, w_scale: jax.Array):
    """Per-tensor weight quantization.  With automatic scaling the scale
    is the *predicted* one — no max-reduction over w in the HLO.

    Pre-quantized serving weights (fp8 dtype, built once by
    ``prequantize_params``) pass straight through: ``w_scale`` is their
    build-time dequant scale and the graph contains neither the cast
    nor the reduction."""
    if _is_fp8(w):
        return PerTensorQ(q=w, s=jnp.asarray(w_scale, jnp.float32))
    if cfg.weight_cast_bf16:
        w = w.astype(jnp.bfloat16)
    if cfg.weight_scaling == "auto":
        return quant_per_tensor(w, cfg.fwd_format, scale=w_scale)
    return quant_per_tensor(w, cfg.fwd_format)  # jit/delayed: reduce now


def _fwd_gemm(cfg: QuantConfig, x2d: jax.Array, wq: PerTensorQ):
    """Forward GEMM via the unified kernel dispatch (repro.kernels.
    dispatch): Pallas-native on TPU, interpret-mode Pallas under
    REPRO_KERNELS=interpret, jnp reference on CPU."""
    from repro.kernels import dispatch

    if cfg.mode == "moss":
        # fused quantize+GEMM: one pass over x, residual (q, sexp)
        # emitted by the same kernel (paper Fig. 3b steady state)
        wq_p = PerTensorQ(q=_pad_axis(wq.q, 0, cfg.micro_group), s=wq.s)
        y, xq = dispatch.fused_quant_matmul(
            _pad_axis(x2d, -1, cfg.micro_group), wq_p,
            fmt=cfg.fwd_format, micro_group=cfg.micro_group,
            out_dtype=jnp.float32)
        return y, xq
    if cfg.mode == "per_group":
        xq = quant_per_group(_pad_axis(x2d, -1, cfg.group_size),
                             cfg.group_size, cfg.fwd_format)
        wq_p = PerTensorQ(q=_pad_axis(wq.q, 0, cfg.group_size), s=wq.s)
        y = dispatch.group_matmul(xq, wq_p, out_dtype=jnp.float32)
        return y, xq
    # per_tensor
    xq = quant_per_tensor(x2d, cfg.fwd_format)
    return dispatch.pt_matmul(xq, wq, out_dtype=jnp.float32), xq


def _qmm_fwd(cfg: QuantConfig, x, w, w_scale):
    orig_dtype = x.dtype
    *lead, k = x.shape
    if cfg.mode == "bf16":
        from .runtime_flags import mm

        y = mm(x, w, out_dtype=jnp.float32)
        # residual: the bf16 activation (what MOSS avoids storing);
        # zero-size witnesses carry the primal dtypes for the cotangents
        return y.astype(orig_dtype), (x.astype(jnp.bfloat16),
                                      w.astype(jnp.bfloat16),
                                      jnp.zeros((0,), x.dtype),
                                      jnp.zeros((0,), w.dtype))
    x2d = x.reshape(-1, k)
    wq = _quantize_w(cfg, w, w_scale)
    y2d, xq = _fwd_gemm(cfg, x2d, wq)
    y = y2d.reshape(*lead, w.shape[-1]).astype(orig_dtype)
    # fp8 residuals only — the activation-memory saving.  (cfg is static,
    # so the backward knows the mode without a runtime tag; the empty
    # array is a dtype witness for the weight cotangent.)
    return y, (xq, wq, jnp.zeros((0,), w.dtype))


def _qmm_bwd(cfg: QuantConfig, res, g):
    if cfg.mode == "bf16":
        from .runtime_flags import mm

        x_bf16, w_bf16, x_wit, w_wit = res
        *lead, k = x_bf16.shape
        g2d = g.reshape(-1, g.shape[-1])
        dx = mm(g2d, w_bf16.T, out_dtype=jnp.float32)
        dw = mm(x_bf16.reshape(-1, k).T, g2d, out_dtype=jnp.float32)
        return (dx.reshape(*lead, k).astype(x_wit.dtype),
                dw.astype(w_wit.dtype), jnp.zeros((), jnp.float32))

    from repro.kernels import dispatch

    xq, wq, w_witness = res
    lead = g.shape[:-1]
    k = wq.q.shape[0]
    x_dtype = g.dtype
    w_dtype = w_witness.dtype
    n = wq.q.shape[-1]
    g2d = g.reshape(-1, n).astype(jnp.float32)
    bfmt = cfg.bwd_format

    # ---- dx = g @ Wᵀ : inner dim N; g grouped along N (E5M2), Wᵀ
    # per-tensor.  MOSS path: fused quantize+GEMM kernel, same operator
    # as the forward.
    if cfg.mode == "moss":
        wqT = PerTensorQ(q=_pad_axis(wq.q.T, 0, cfg.micro_group), s=wq.s)
        dx2d, _ = dispatch.fused_quant_matmul(
            _pad_axis(g2d, -1, cfg.micro_group), wqT, fmt=bfmt,
            micro_group=cfg.micro_group, out_dtype=jnp.float32)
    elif cfg.mode == "per_group":
        gq = quant_per_group(_pad_axis(g2d, -1, cfg.group_size),
                             cfg.group_size, bfmt)
        wqT = PerTensorQ(q=_pad_axis(wq.q.T, 0, cfg.group_size), s=wq.s)
        dx2d = dispatch.group_matmul(gq, wqT, out_dtype=jnp.float32)
    else:
        gq = quant_per_tensor(g2d, bfmt)
        dx2d = dispatch.pt_matmul(gq, PerTensorQ(q=wq.q.T, s=wq.s),
                                  out_dtype=jnp.float32)
    dx = dx2d[:, :k].reshape(*lead, k).astype(x_dtype)

    # ---- dW = xᵀ @ g : inner dim M (tokens); re-quantize the saved fp8
    # activation grouped along M (documented extra quantization — same
    # trade as COAT's transposed copy).  MOSS path: the dW kernel fuses
    # dequant → transpose → requant_M → GEMM, pinning the requant's
    # level-1 scale to s_x so no second amax reduction appears
    # (kernels/mx_bwd.py).
    if cfg.mode == "moss":
        g_pt = quant_per_tensor(g2d, bfmt)
        dw = dispatch.mx_matmul_dw(xq, g_pt, fmt=cfg.fwd_format,
                                   out_dtype=jnp.float32, out_rows=k)
    elif cfg.mode == "per_group":
        x2d = xq.dequant(jnp.bfloat16)[:, :k]     # (M, K) from fp8 residual
        xTq = quant_per_group(_pad_axis(x2d.T, -1, cfg.group_size),
                              cfg.group_size, cfg.fwd_format)
        g_pt = quant_per_tensor(_pad_axis(g2d, 0, cfg.group_size), bfmt)
        dw = dispatch.group_matmul(xTq, g_pt, out_dtype=jnp.float32)
    else:
        x2d = xq.dequant(jnp.bfloat16)
        xTq = quant_per_tensor(x2d.T, cfg.fwd_format)
        g_pt = quant_per_tensor(g2d, bfmt)
        dw = dispatch.pt_matmul(xTq, g_pt, out_dtype=jnp.float32)
    dw = dw.astype(w_dtype)

    return dx, dw, jnp.zeros((), jnp.float32)


qmm.defvjp(_qmm_fwd, _qmm_bwd)


# ---------------------------------------------------------------------------
# Grouped-expert custom_vjp:  (cfg, capacity static)
#   (x, w_stack, w_scale, group_sizes) -> y
#   x: (E·C, K) flat sorted token buffer (expert e owns rows
#      [e·C, e·C + group_sizes[e]); the rest of each slot is zero)
#   w_stack: (E, K, N)   w_scale: (E,) f32   group_sizes: (E,) int32
#
# The MoE hot path: all expert GEMMs in ONE grouped kernel launch with
# ONE global amax reduction over the token buffer (vs 3·E launches + E
# reductions on the vmapped per-expert path).  Residuals are the fp8
# payload of the whole buffer — same 1.8× activation saving as qmm.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def qmm_grouped(cfg: QuantConfig, capacity: int, x: jax.Array,
                w_stack: jax.Array, w_scale: jax.Array,
                group_sizes: jax.Array) -> jax.Array:
    y, _ = _qmm_grouped_fwd(cfg, capacity, x, w_stack, w_scale,
                            group_sizes)
    return y


def _quantize_w_stack(cfg: QuantConfig, w: jax.Array, w_scale: jax.Array):
    """Per-expert per-tensor weight quantization of the (E, K, N) stack:
    ``_quantize_w`` vmapped over the expert dim, so with automatic
    scaling the per-expert scales are the predicted ones (no
    max-reduction over the stack in the HLO)."""
    return jax.vmap(lambda wi, si: _quantize_w(cfg, wi, si))(w, w_scale)


def _qmm_grouped_fwd(cfg: QuantConfig, capacity: int, x, w_stack,
                     w_scale, group_sizes):
    orig_dtype = x.dtype
    e, k, n = w_stack.shape
    if cfg.mode == "bf16":
        from .runtime_flags import einsum

        y = einsum("eck,ekn->ecn", x.reshape(e, capacity, k), w_stack,
                   out_dtype=jnp.float32)
        return (y.reshape(e * capacity, n).astype(orig_dtype),
                (x.astype(jnp.bfloat16), w_stack.astype(jnp.bfloat16),
                 group_sizes, jnp.zeros((0,), x.dtype),
                 jnp.zeros((0,), w_stack.dtype)))
    assert cfg.mode == "moss", \
        f"qmm_grouped supports moss/bf16 modes, got {cfg.mode!r}"
    from repro.kernels import dispatch

    wq = _quantize_w_stack(cfg, w_stack, w_scale)
    y, xq = dispatch.moe_grouped_matmul(
        _pad_axis(x, -1, cfg.micro_group), group_sizes,
        _pad_axis(wq.q, 1, cfg.micro_group), wq.s,
        capacity=capacity, fmt=cfg.fwd_format,
        micro_group=cfg.micro_group, out_dtype=jnp.float32)
    return (y.astype(orig_dtype),
            (xq, wq, group_sizes, jnp.zeros((0,), w_stack.dtype)))


def _qmm_grouped_bwd(cfg: QuantConfig, capacity: int, res, g):
    import numpy as np

    if cfg.mode == "bf16":
        from .runtime_flags import einsum

        x_bf16, w_bf16, sizes, x_wit, w_wit = res
        e, k, n = w_bf16.shape
        g3 = g.reshape(e, capacity, n)
        dx = einsum("ecn,ekn->eck", g3, w_bf16, out_dtype=jnp.float32)
        dw = einsum("eck,ecn->ekn", x_bf16.reshape(e, capacity, k), g3,
                    out_dtype=jnp.float32)
        return (dx.reshape(e * capacity, k).astype(x_wit.dtype),
                dw.astype(w_wit.dtype), jnp.zeros((e,), jnp.float32),
                np.zeros(sizes.shape, jax.dtypes.float0))

    from repro.kernels import dispatch

    xq, wq, sizes, w_witness = res
    e, k, n = wq.q.shape
    g2d = g.astype(jnp.float32)
    bfmt = cfg.bwd_format

    # ---- dx: the same grouped fused-quant GEMM with transposed expert
    # weights (g grouped along N in E5M2) — one launch, one reduction.
    wqT = jnp.swapaxes(wq.q, 1, 2)                     # (E, N, K)
    dx, _ = dispatch.moe_grouped_matmul(
        _pad_axis(g2d, -1, cfg.micro_group), sizes,
        _pad_axis(wqT, 1, cfg.micro_group), wq.s,
        capacity=capacity, fmt=bfmt, micro_group=cfg.micro_group,
        out_dtype=jnp.float32)
    dx = dx.astype(g.dtype)

    # ---- dW: grouped requant-along-tokens GEMM over each expert's row
    # range; the gradient buffer gets ONE per-tensor scale (vs E).
    g_pt = quant_per_tensor(g2d, bfmt)
    dw = dispatch.moe_grouped_matmul_dw(
        xq, g_pt, sizes, capacity=capacity, fmt=cfg.fwd_format,
        out_dtype=jnp.float32, out_rows=k)
    return (dx, dw.astype(w_witness.dtype), jnp.zeros((e,), jnp.float32),
            np.zeros(sizes.shape, jax.dtypes.float0))


qmm_grouped.defvjp(_qmm_grouped_fwd, _qmm_grouped_bwd)


# ---------------------------------------------------------------------------
# Public layer API
# ---------------------------------------------------------------------------


def qlinear(x: jax.Array, wt: QT, cfg: QuantConfig) -> jax.Array:
    """Quantized ``x @ w``.  ``wt`` bundles the weight and its predicted
    scale; falls back to in-step (jit) scaling when the scale is None.
    A calibrated ``wt.a`` (ActScale) takes the reduction-free delayed-
    activation forward instead (docs/serving.md)."""
    if cfg.mode == "bf16":
        return qmm(cfg, x, wt.w, jnp.zeros((), jnp.float32))
    a = wt.a
    if isinstance(a, str):
        # calibration pass: report this site's activation amax, then
        # run the normal just-in-time forward (what we're calibrating)
        if REC.recording:
            REC.record(a, x, cfg)
        a = None
    if isinstance(a, ActScale):
        return _qmm_delayed(cfg, x, wt, a)
    if a is not None:
        # quant-health tap (repro.obs.quant_health, REPRO_QUANT_HEALTH=1
        # only — a TaggedScale never exists otherwise): record this
        # site's saturation/underflow/drift stats, then run the same
        # delayed forward the bare ActScale takes
        from repro.obs.quant_health import QH, TaggedScale

        if isinstance(a, TaggedScale):
            QH.record(a.tag, x, a.scale, cfg)
            return _qmm_delayed(cfg, x, wt, a.scale)
    s = wt.s
    if s is None:
        # no predicted scale available → behave like jit scaling
        cfg = QuantConfig(**{**cfg.__dict__, "weight_scaling": "jit"}) \
            if cfg.weight_scaling == "auto" else cfg
        s = jnp.ones((), jnp.float32)
    return qmm(cfg, x, wt.w, s)


def _qmm_delayed(cfg: QuantConfig, x: jax.Array, wt: QT,
                 a: ActScale) -> jax.Array:
    """Serving-only (forward, no VJP) quantized GEMM that consumes the
    site's calibrated activation scales instead of measuring them: the
    quantize is a rescale + saturating cast, with **zero** reductions in
    the graph (``core.introspect.count_quant_reductions``).  Weights
    ride the same pre-quantized fast path as the just-in-time forward
    (``_quantize_w``); the GEMM itself goes through the identical
    kernel-dispatch entry points, so a calibrated scale equal to the
    just-in-time one reproduces its output bitwise."""
    from repro.kernels import dispatch

    orig_dtype = x.dtype
    *lead, k = x.shape
    x2d = x.reshape(-1, k)
    if wt.s is None and not _is_fp8(wt.w):
        # hatch combo (REPRO_SERVE_PREQUANT=0, non-auto recipe): weight
        # still quantizes in-graph, only the activation side is delayed
        wcfg = QuantConfig(**{**cfg.__dict__, "weight_scaling": "jit"}) \
            if cfg.weight_scaling == "auto" else cfg
        wq = _quantize_w(wcfg, wt.w, jnp.ones((), jnp.float32))
    else:
        wq = _quantize_w(cfg, wt.w, wt.s if wt.s is not None
                         else jnp.ones((), jnp.float32))

    if cfg.mode == "moss":
        x2d = _pad_axis(x2d, -1, cfg.micro_group)
        xq = quant_mx_delayed(x2d, a.s, a.sub, cfg.micro_group,
                              cfg.fwd_format)
        wq_p = PerTensorQ(q=_pad_axis(wq.q, 0, cfg.micro_group), s=wq.s)
        y2d = dispatch.mx_matmul(xq, wq_p, out_dtype=jnp.float32)
    elif cfg.mode == "per_group":
        x2d = _pad_axis(x2d, -1, cfg.group_size)
        g = x2d.shape[-1] // cfg.group_size
        s = jnp.broadcast_to(a.s.astype(jnp.float32),
                             (x2d.shape[0], g))
        xq = quant_per_group(x2d, cfg.group_size, cfg.fwd_format, scale=s)
        wq_p = PerTensorQ(q=_pad_axis(wq.q, 0, cfg.group_size), s=wq.s)
        y2d = dispatch.group_matmul(xq, wq_p, out_dtype=jnp.float32)
    else:  # per_tensor
        xq = quant_per_tensor(x2d, cfg.fwd_format, scale=a.s)
        y2d = dispatch.pt_matmul(xq, wq, out_dtype=jnp.float32)
    return y2d.reshape(*lead, wt.w.shape[-1]).astype(orig_dtype)


def qlinear_grouped(x_flat: jax.Array, wt: QT, group_sizes: jax.Array,
                    capacity: int, cfg: QuantConfig) -> jax.Array:
    """Grouped-expert qlinear: the flat sorted token buffer
    ``x_flat (E·C, K)`` against the stacked expert weights
    ``wt.w (E, K, N)`` with per-expert predicted scales ``wt.s (E,)``.
    Falls back to in-step (jit) per-expert scaling when scales are
    missing, mirroring ``qlinear``."""
    e = wt.w.shape[0]
    if cfg.mode == "bf16":
        return qmm_grouped(cfg, capacity, x_flat, wt.w,
                           jnp.zeros((e,), jnp.float32), group_sizes)
    s = wt.s
    if s is None:
        cfg = QuantConfig(**{**cfg.__dict__, "weight_scaling": "jit"}) \
            if cfg.weight_scaling == "auto" else cfg
        s = jnp.ones((e,), jnp.float32)
    return qmm_grouped(cfg, capacity, x_flat, wt.w, s, group_sizes)


def dense_general(x: jax.Array, wt: QT, cfg: QuantConfig,
                  out_features_shape: tuple[int, ...] | None = None):
    """qlinear for weights whose logical out-dim is multi-axis (e.g.
    (K, H, Dh)): flattens trailing axes for the GEMM, reshapes back."""
    w = wt.w
    if w.ndim > 2:
        k = w.shape[0]
        wf = w.reshape(k, -1)
        y = qlinear(x, QT(wf, wt.s, wt.a), cfg)
        return y.reshape(*x.shape[:-1], *w.shape[1:])
    y = qlinear(x, wt, cfg)
    if out_features_shape:
        y = y.reshape(*x.shape[:-1], *out_features_shape)
    return y
