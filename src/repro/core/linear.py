"""Quantized linear layers with custom VJP — the MOSS training integration.

``qmm(cfg, x, w, w_scale)`` computes ``x @ w`` under the configured FP8
recipe with a fully custom backward:

  forward   y  = MXFP8-GEMM(Qx, Qw) · s_x·s_w          (E4M3 operands)
  residuals fp8 Qx (+ E8M0 exponents + one f32) and fp8 Qw — this is the
            paper's 1.8× activation-memory saving: backward never needs
            the bf16 activation.
  backward  dx = MXFP8-GEMM(Qg, Qwᵀ)                    (g in E5M2)
            dW = MXFP8-GEMM(requant_M(Qx)ᵀ, Qg)         (inner dim = tokens)

Weight scales come from MOSS automatic scaling (``w_scale`` argument,
predicted by ``repro.core.autoscale``) so no max|W| reduction appears in
the steady-state HLO.

All four recipes are selectable for baseline comparisons: ``bf16``,
``per_tensor`` (TE-style), ``per_group`` (COAT-style), ``moss``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .formats import QuantConfig
from .quant import (
    MxQ,
    PerGroupQ,
    PerTensorQ,
    group_gemm,
    mx_gemm,
    pt_gemm,
    quant_mx,
    quant_per_group,
    quant_per_tensor,
)


class QT(NamedTuple):
    """A weight tensor bundled with its (possibly predicted) fp8 scale.

    ``s`` is None in bf16 mode or for never-quantized params (norms,
    routers, recurrence gates); model code unwraps ``.w`` for those.
    """

    w: jax.Array
    s: jax.Array | None = None


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad ``axis`` up to a multiple of ``mult`` (zeros are exact
    under all our quantizers: amax of a zero group is clamped to TINY)."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# custom_vjp core:  (cfg static) (x, w, w_scale) -> y
#   x: (..., K)   w: (K, N)   w_scale: f32 scalar or None-like scalar
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def qmm(cfg: QuantConfig, x: jax.Array, w: jax.Array,
        w_scale: jax.Array) -> jax.Array:
    y, _ = _qmm_fwd(cfg, x, w, w_scale)
    return y


def _quantize_w(cfg: QuantConfig, w: jax.Array, w_scale: jax.Array):
    """Per-tensor weight quantization.  With automatic scaling the scale
    is the *predicted* one — no max-reduction over w in the HLO."""
    if cfg.weight_cast_bf16:
        w = w.astype(jnp.bfloat16)
    if cfg.weight_scaling == "auto":
        return quant_per_tensor(w, cfg.fwd_format, scale=w_scale)
    return quant_per_tensor(w, cfg.fwd_format)  # jit/delayed: reduce now


def _fwd_gemm(cfg: QuantConfig, x2d: jax.Array, wq: PerTensorQ):
    k = x2d.shape[-1]
    if cfg.mode == "moss":
        xq = quant_mx(_pad_axis(x2d, -1, cfg.micro_group), cfg.micro_group,
                      cfg.fwd_format)
        wq_p = PerTensorQ(q=_pad_axis(wq.q, 0, cfg.micro_group), s=wq.s)
        y = mx_gemm(xq, wq_p, out_dtype=jnp.float32)
        return y, xq
    if cfg.mode == "per_group":
        xq = quant_per_group(_pad_axis(x2d, -1, cfg.group_size),
                             cfg.group_size, cfg.fwd_format)
        wq_p = PerTensorQ(q=_pad_axis(wq.q, 0, cfg.group_size), s=wq.s)
        y = group_gemm(xq, wq_p, out_dtype=jnp.float32)
        return y, xq
    # per_tensor
    xq = quant_per_tensor(x2d, cfg.fwd_format)
    return pt_gemm(xq, wq, out_dtype=jnp.float32), xq


def _qmm_fwd(cfg: QuantConfig, x, w, w_scale):
    orig_dtype = x.dtype
    *lead, k = x.shape
    if cfg.mode == "bf16":
        from .runtime_flags import mm

        y = mm(x, w, out_dtype=jnp.float32)
        # residual: the bf16 activation (what MOSS avoids storing);
        # zero-size witnesses carry the primal dtypes for the cotangents
        return y.astype(orig_dtype), (x.astype(jnp.bfloat16),
                                      w.astype(jnp.bfloat16),
                                      jnp.zeros((0,), x.dtype),
                                      jnp.zeros((0,), w.dtype))
    x2d = x.reshape(-1, k)
    wq = _quantize_w(cfg, w, w_scale)
    y2d, xq = _fwd_gemm(cfg, x2d, wq)
    y = y2d.reshape(*lead, w.shape[-1]).astype(orig_dtype)
    # fp8 residuals only — the activation-memory saving.  (cfg is static,
    # so the backward knows the mode without a runtime tag; the empty
    # array is a dtype witness for the weight cotangent.)
    return y, (xq, wq, jnp.zeros((0,), w.dtype))


def _bwd_quant_lhs(cfg: QuantConfig, a2d: jax.Array, fmt: str):
    """Quantize a backward GEMM's LHS grouped along its (last) inner dim."""
    if cfg.mode == "moss":
        return quant_mx(_pad_axis(a2d, -1, cfg.micro_group),
                        cfg.micro_group, fmt), "moss"
    if cfg.mode == "per_group":
        return quant_per_group(_pad_axis(a2d, -1, cfg.group_size),
                               cfg.group_size, fmt), "per_group"
    return quant_per_tensor(a2d, fmt), "per_tensor"


def _bwd_gemm(kind: str, lhs, rhs: PerTensorQ, out_dtype):
    """Dispatch a backward GEMM; the caller pads rhs's inner dim."""
    if kind == "moss":
        return mx_gemm(lhs, rhs, out_dtype=out_dtype)
    if kind == "per_group":
        return group_gemm(lhs, rhs, out_dtype=out_dtype)
    return pt_gemm(lhs, rhs, out_dtype=out_dtype)


def _qmm_bwd(cfg: QuantConfig, res, g):
    if cfg.mode == "bf16":
        from .runtime_flags import mm

        x_bf16, w_bf16, x_wit, w_wit = res
        *lead, k = x_bf16.shape
        g2d = g.reshape(-1, g.shape[-1])
        dx = mm(g2d, w_bf16.T, out_dtype=jnp.float32)
        dw = mm(x_bf16.reshape(-1, k).T, g2d, out_dtype=jnp.float32)
        return (dx.reshape(*lead, k).astype(x_wit.dtype),
                dw.astype(w_wit.dtype), jnp.zeros((), jnp.float32))

    xq, wq, w_witness = res
    lead = g.shape[:-1]
    k = wq.q.shape[0]
    x_dtype = g.dtype
    w_dtype = w_witness.dtype
    n = wq.q.shape[-1]
    g2d = g.reshape(-1, n).astype(jnp.float32)
    bfmt = cfg.bwd_format

    # ---- dx = g @ Wᵀ : inner dim N; g grouped along N (E5M2), Wᵀ per-tensor
    gq, kind = _bwd_quant_lhs(cfg, g2d, bfmt)
    group = cfg.micro_group if cfg.mode == "moss" else cfg.group_size
    if cfg.mode == "per_tensor":
        wqT = PerTensorQ(q=wq.q.T, s=wq.s)
    else:
        # pad Wᵀ's inner (N) axis to match the padded/grouped g
        wqT = PerTensorQ(q=_pad_axis(wq.q.T, 0, group), s=wq.s)
    dx2d = _bwd_gemm(kind, gq, wqT, jnp.float32)
    dx2d = dx2d[:, :k]
    dx = dx2d.reshape(*lead, k).astype(x_dtype)

    # ---- dW = xᵀ @ g : inner dim M (tokens); dequantize the saved fp8
    # activation and re-quantize grouped along M (documented extra
    # quantization — same trade as COAT's transposed copy).  bf16 dequant
    # halves the transient buffer; error ≪ the fp8 noise floor.
    x2d = xq.dequant(jnp.bfloat16)[:, :k]         # (M, K) from fp8 residual
    m = x2d.shape[0]
    xTq, kind = _bwd_quant_lhs(cfg, x2d.T, cfg.fwd_format)   # (K, M) grp M
    g_pt = quant_per_tensor(_pad_axis(g2d, 0, group)
                            if cfg.mode != "per_tensor" else g2d, bfmt)
    dw = _bwd_gemm(kind, xTq, g_pt, jnp.float32)
    dw = dw.astype(w_dtype)

    return dx, dw, jnp.zeros((), jnp.float32)


qmm.defvjp(_qmm_fwd, _qmm_bwd)


# ---------------------------------------------------------------------------
# Public layer API
# ---------------------------------------------------------------------------


def qlinear(x: jax.Array, wt: QT, cfg: QuantConfig) -> jax.Array:
    """Quantized ``x @ w``.  ``wt`` bundles the weight and its predicted
    scale; falls back to in-step (jit) scaling when the scale is None."""
    if cfg.mode == "bf16":
        return qmm(cfg, x, wt.w, jnp.zeros((), jnp.float32))
    s = wt.s
    if s is None:
        # no predicted scale available → behave like jit scaling
        cfg = QuantConfig(**{**cfg.__dict__, "weight_scaling": "jit"}) \
            if cfg.weight_scaling == "auto" else cfg
        s = jnp.ones((), jnp.float32)
    return qmm(cfg, x, wt.w, s)


def dense_general(x: jax.Array, wt: QT, cfg: QuantConfig,
                  out_features_shape: tuple[int, ...] | None = None):
    """qlinear for weights whose logical out-dim is multi-axis (e.g.
    (K, H, Dh)): flattens trailing axes for the GEMM, reshapes back."""
    w = wt.w
    if w.ndim > 2:
        k = w.shape[0]
        wf = w.reshape(k, -1)
        y = qlinear(x, QT(wf, wt.s), cfg)
        return y.reshape(*x.shape[:-1], *w.shape[1:])
    y = qlinear(x, wt, cfg)
    if out_features_shape:
        y = y.reshape(*x.shape[:-1], *out_features_shape)
    return y
