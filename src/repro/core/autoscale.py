"""MOSS automatic scaling for weight tensors (paper §3.2).

AdamW updates are bounded by the step size:  |ΔW_t| ≤ η  (paper Thm 2),
hence  max|W_t| ≤ max|W_0| + η·t  and the per-tensor weight scale can be
*predicted* instead of measured:

    s_t = s_0 + η · (t - t_refresh) / FP8_MAX            (paper Eq. 10)

A real max-reduction runs only every ``rescale_interval`` steps.  Between
refreshes the predicted scale strictly upper-bounds the just-in-time
scale, so the quantized weights can never overflow (paper Fig 4).

State is a pytree threaded through the jitted train step; the refresh is
a ``lax.cond`` so the max-reduction bytes appear in the HLO only on the
refresh branch (and the roofline's memory term drops accordingly).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .formats import TINY, QuantConfig, fp8_max


class ScaleState(NamedTuple):
    """Automatic-scaling state for ONE weight tensor."""

    s0: jax.Array            # f32 scale measured at the last refresh
    steps_since: jax.Array   # i32 steps since last refresh


def init_scale_state(w: jax.Array, cfg: QuantConfig) -> ScaleState:
    """s_0 from a real max-reduction at initialization (paper: 'determined
    via a max-reduction operation at initialization')."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    s0 = jnp.maximum(amax, TINY) / fp8_max(cfg.fwd_format)
    return ScaleState(s0=s0, steps_since=jnp.zeros((), jnp.int32))


def predicted_scale(state: ScaleState, lr: jax.Array,
                    cfg: QuantConfig) -> jax.Array:
    """Paper Eq. (10): s_t = s_0 + η·t / FP8_MAX (t counted since refresh)."""
    t = state.steps_since.astype(jnp.float32)
    return state.s0 + lr.astype(jnp.float32) * t / fp8_max(cfg.fwd_format)


def update_scale_state(state: ScaleState, w: jax.Array,
                       cfg: QuantConfig) -> ScaleState:
    """Advance one step; every ``rescale_interval`` steps run the real
    max-reduction (lax.cond → untaken branch reads no weight bytes)."""
    t_next = state.steps_since + 1

    def refresh(_):
        amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
        s0 = jnp.maximum(amax, TINY) / fp8_max(cfg.fwd_format)
        return ScaleState(s0=s0, steps_since=jnp.zeros((), jnp.int32))

    def predict(_):
        return ScaleState(s0=state.s0, steps_since=t_next)

    if cfg.weight_scaling == "jit":
        return refresh(None)        # max-reduce every step
    if cfg.weight_scaling == "delayed":
        # delayed scaling: refresh every step but the scale *used* this
        # step was last step's (callers read the scale before update).
        return refresh(None)
    return jax.lax.cond(t_next >= cfg.rescale_interval, refresh, predict,
                        operand=None)


def tree_init_scale_states(params, cfg: QuantConfig):
    """ScaleState for every weight tensor in a param pytree."""
    return jax.tree.map(lambda w: init_scale_state(w, cfg), params)


def tree_update_scale_states(states, params, cfg: QuantConfig):
    return jax.tree.map(
        lambda st, w: update_scale_state(st, w, cfg), states, params,
        is_leaf=lambda x: isinstance(x, ScaleState))


def tree_predicted_scales(states, lr, cfg: QuantConfig):
    return jax.tree.map(
        lambda st: predicted_scale(st, lr, cfg), states,
        is_leaf=lambda x: isinstance(x, ScaleState))
