"""Quantization schemes: per-tensor, per-group (COAT) and MOSS two-level
microscaling — pure-jnp implementations.

These are the *semantic* definitions.  ``repro.kernels`` holds the
Pallas TPU kernels whose oracles are these functions; on CPU (this
container) the linear layers run these directly and XLA fuses them.

Conventions
-----------
Quantization for a GEMM ``y = x @ w`` groups along the **inner (K)
dimension**, which is the *last* axis of ``x`` and the *first* of ``w``.
All public quantizers here group along the last axis; callers transpose
as needed.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .formats import (
    TINY,
    FP8Format,
    QuantConfig,
    cast_fp8,
    e8m0_decode,
    e8m0_encode,
    fp8_dtype,
    fp8_max,
)


class PerTensorQ(NamedTuple):
    """TE-style per-tensor quantization: q ≈ x / s."""

    q: jax.Array          # fp8
    s: jax.Array          # f32 scalar

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        return self.q.astype(jnp.float32).astype(dtype) * self.s.astype(dtype)


class PerGroupQ(NamedTuple):
    """COAT-style per-group quantization along the last axis."""

    q: jax.Array          # fp8, shape (..., K)
    s: jax.Array          # f32, shape (..., K // group)

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        g = self.q.shape[-1] // self.s.shape[-1]
        qf = self.q.astype(jnp.float32).reshape(*self.q.shape[:-1], -1, g)
        x = qf * self.s[..., None]
        return x.reshape(self.q.shape).astype(dtype)


class MxQ(NamedTuple):
    """MOSS two-level microscaled tensor.

    q      fp8 values, shape (..., K)
    sexp   int8 E8M0 exponents (level-2), shape (..., K // micro_group)
    s      f32 global scale (level-1), scalar

    Effective per-group scale is ``s * 2^sexp`` with ``2^sexp ∈ (0,1]``.
    """

    q: jax.Array
    sexp: jax.Array
    s: jax.Array

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        g = self.q.shape[-1] // self.sexp.shape[-1]
        qf = self.q.astype(jnp.float32).reshape(*self.q.shape[:-1], -1, g)
        ss = e8m0_decode(self.sexp)
        x = qf * (ss * self.s)[..., None]
        return x.reshape(self.q.shape).astype(dtype)

    def storage_bits_per_value(self) -> float:
        """fp8 payload + amortized E8M0 metadata (paper's storage claim)."""
        g = self.q.shape[-1] // self.sexp.shape[-1]
        return 8.0 + 8.0 / g


class PrequantParams(NamedTuple):
    """A whole model's weights pre-quantized for serving (built ONCE at
    ``Server`` construction by ``repro.train.steps.prequantize_params``).

    qweights   the params pytree with every *quantized* linear weight
               replaced by its fp8 payload (never-quantized leaves —
               norms, routers, embeddings — stay raw f32/bf16)
    scales     matching pytree of f32 per-(layer, expert)-slice dequant
               scales (one scalar per stacked slice; the leading dims
               mirror the leaf's stacked layer/expert dims)

    ``qweights`` is passed wherever the raw params tree was passed; the
    fp8 dtype is the marker ``repro.core.linear._quantize_w`` uses to
    skip the in-graph quantize + max-reduction entirely.  Payloads are
    bit-identical to what the in-graph quantizer would produce, so
    serving outputs match the in-graph path bitwise
    (tests/test_serving.py).
    """

    qweights: jax.Array | dict
    scales: jax.Array | dict


def prequant_weight(w: jax.Array, n_stacked: int, fmt: FP8Format = "e4m3",
                    scale: jax.Array | None = None,
                    cast_bf16: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """Build-time per-tensor fp8 quantization of one stacked weight leaf.

    ``w`` has ``n_stacked`` leading layer/expert dims, each slice getting
    an independent per-tensor scale (shape ``w.shape[:n_stacked]``) —
    exactly the slices the scan-over-layers forward quantizes one at a
    time.  Bitwise-matches the in-graph ``quant_per_tensor``:

      scale = max(amax(slice), TINY) / FP8_MAX   (or the supplied
              predicted scale, for "auto" recipes)
      q     = saturating_cast_fp8(slice / scale)

    ``cast_bf16`` replicates ``QuantConfig.weight_cast_bf16`` (the bf16
    round-trip before quantization).  Returns ``(q fp8, scale f32)``.
    """
    if cast_bf16:
        w = w.astype(jnp.bfloat16)
    wf = w.astype(jnp.float32)
    if scale is None:
        axes = tuple(range(n_stacked, w.ndim))
        amax = jnp.max(jnp.abs(wf), axis=axes)
        scale = jnp.maximum(amax, TINY) / fp8_max(fmt)
    scale = jnp.asarray(scale, jnp.float32)
    sb = scale.reshape(scale.shape + (1,) * (w.ndim - scale.ndim))
    return cast_fp8(wf / sb, fmt), scale


# ---------------------------------------------------------------------------
# Quantizers
# ---------------------------------------------------------------------------


def quant_per_tensor(x: jax.Array, fmt: FP8Format = "e4m3",
                     scale: jax.Array | None = None) -> PerTensorQ:
    """One f32 scale for the whole tensor.  ``scale`` may be supplied
    externally (e.g. by MOSS automatic scaling) to skip the max-reduction."""
    if scale is None:
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        scale = jnp.maximum(amax, TINY) / fp8_max(fmt)
    scale = jnp.asarray(scale, jnp.float32)
    q = cast_fp8(x.astype(jnp.float32) / scale, fmt)
    return PerTensorQ(q=q, s=scale)


def quant_per_group(x: jax.Array, group: int = 128,
                    fmt: FP8Format = "e4m3",
                    scale: jax.Array | None = None) -> PerGroupQ:
    """COAT-style per-group scales along the last axis.  ``scale``
    (shape ``(..., K // group)``) may be supplied externally (the
    delayed-activation serving path) to skip the amax reduction; values
    beyond ``scale · FP8_MAX`` saturate via the clipping cast."""
    *lead, k = x.shape
    assert k % group == 0, f"K={k} not divisible by group={group}"
    xg = x.astype(jnp.float32).reshape(*lead, k // group, group)
    if scale is None:
        amax = jnp.max(jnp.abs(xg), axis=-1)
        s = jnp.maximum(amax, TINY) / fp8_max(fmt)
    else:
        s = jnp.asarray(scale, jnp.float32)
    q = cast_fp8(xg / s[..., None], fmt).reshape(x.shape)
    return PerGroupQ(q=q, s=s)


def quant_mx(x: jax.Array, micro_group: int = 32, fmt: FP8Format = "e4m3",
             global_scale: jax.Array | None = None) -> MxQ:
    """MOSS two-level microscaling (paper Eqs. 2–3).

    1. per-micro-group fine scale   s_g = amax_g / FP8_MAX
    2. level-1 global scale         s   = max_g s_g   (or supplied)
    3. level-2 E8M0 subscale        ss_g = 2^ceil(log2(s_g / s)) ∈ (0,1]
    4. values                       q = cast_fp8(x / (s·ss_g))
    """
    *lead, k = x.shape
    assert k % micro_group == 0, f"K={k} not divisible by {micro_group}"
    xf = x.astype(jnp.float32)
    xg = xf.reshape(*lead, k // micro_group, micro_group)
    amax_g = jnp.max(jnp.abs(xg), axis=-1)
    s_g = amax_g / fp8_max(fmt)
    if global_scale is None:
        s = jnp.maximum(jnp.max(s_g), TINY)
    else:
        s = jnp.maximum(jnp.asarray(global_scale, jnp.float32), TINY)
    sexp = e8m0_encode(s_g / s)
    ss = e8m0_decode(sexp)
    # ss·s can underflow f32 to 0 for tiny-magnitude tensors (e.g. late
    # gradients: s ~ 1e-20, ss = 2^-127).  A zero denominator means the
    # group's values are below f32 resolution relative to the tensor —
    # quantize them to 0 (dequant multiplies by the same 0: consistent).
    denom = (ss * s)[..., None]
    q = cast_fp8(jnp.where(denom > 0, xg / jnp.where(denom > 0, denom, 1.0),
                           0.0), fmt).reshape(x.shape)
    return MxQ(q=q, sexp=sexp, s=s)


def quant_mx_delayed(x: jax.Array, global_scale: jax.Array,
                     sexp: jax.Array, micro_group: int = 32,
                     fmt: FP8Format = "e4m3") -> MxQ:
    """MOSS two-level quantization against *pre-computed* scales — the
    reduction-free counterpart of ``quant_mx`` for the delayed-
    activation serving path (``core.actscale``): both the level-1 scale
    and the per-micro-group E8M0 exponents come from calibration, so
    the graph contains no amax reduction at all — just the rescale and
    the saturating fp8 cast (values past the calibrated range clip).

    ``global_scale`` is a scalar; ``sexp`` is int8 E8M0 of shape
    ``(K // micro_group,)`` (or already broadcast to ``(..., K//µg)``)
    and is broadcast to the per-row grid the MX GEMM consumes."""
    *lead, k = x.shape
    assert k % micro_group == 0, f"K={k} not divisible by {micro_group}"
    xg = x.astype(jnp.float32).reshape(*lead, k // micro_group,
                                       micro_group)
    s = jnp.maximum(jnp.asarray(global_scale, jnp.float32), TINY)
    sexp = jnp.broadcast_to(jnp.asarray(sexp, jnp.int8),
                            (*lead, k // micro_group))
    ss = e8m0_decode(sexp)
    # same zero-denominator guard as quant_mx: a group whose effective
    # scale underflows f32 quantizes to 0 (dequant is consistent)
    denom = (ss * s)[..., None]
    q = cast_fp8(jnp.where(denom > 0, xg / jnp.where(denom > 0, denom, 1.0),
                           0.0), fmt).reshape(x.shape)
    return MxQ(q=q, sexp=sexp, s=s)


def quant_excursions(x_abs: jax.Array, scale: jax.Array,
                     fmt: FP8Format = "e4m3"):
    """Out-of-range accounting for a saturating fp8 cast of
    ``x_abs / scale`` (the quant-health tap — docs/observability.md):

      saturated   elements whose magnitude exceeds ``scale · FP8_MAX``
                  (the cast clamps them to ±FP8_MAX)
      underflowed nonzero elements the cast rounds to exactly 0
      nonzero     the underflow denominator

    ``x_abs`` is |x| (any shape), ``scale`` broadcasts against it; a
    non-positive scale quantizes its group to 0, matching the
    zero-denominator guard in ``quant_mx``/``quant_mx_delayed``.
    Returns f32 count scalars.  Nothing here feeds the GEMM — no new
    quantization reductions appear in a graph that calls this.

    Underflow is detected by threshold, not by materializing the cast:
    round-to-nearest-even sends ``v`` to 0 exactly when
    ``v <= smallest_subnormal / 2`` (the tie goes to 0, the even
    side), so ``x <= scale · tie`` is the same predicate one compare
    cheaper — the tap rides every health-sampled serving step and
    must stay a handful of element-wise ops."""
    fmax = fp8_max(fmt)
    tie = float(jnp.finfo(fp8_dtype(fmt)).smallest_subnormal) / 2.0
    xf = x_abs.astype(jnp.float32)
    pos = scale > 0
    sat = jnp.sum(((xf > scale * fmax) & pos).astype(jnp.float32))
    nonzero = xf > 0
    under = jnp.sum((nonzero & ((xf <= scale * tie) | ~pos))
                    .astype(jnp.float32))
    return sat, under, jnp.sum(nonzero.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Quantized GEMM semantics (the reference used by the Pallas kernels and by
# the CPU execution path).  preferred_element_type=f32 models the MXU's f32
# accumulator.
# ---------------------------------------------------------------------------


def mx_gemm(xq: MxQ, wq: PerTensorQ, out_dtype=jnp.bfloat16) -> jax.Array:
    """MOSS GEMM (paper Fig 3b):  y = (Qx · 2^sexp) @ Qw  ·  (s_x · s_w).

    The level-2 exponent scaling rides with the operand (cheap); the single
    f32 dequant `s_x·s_w` happens once in the epilogue.
    """
    from .runtime_flags import mm

    *lead, k = xq.q.shape
    g = k // xq.sexp.shape[-1]
    ss = e8m0_decode(xq.sexp)                                  # (..., K/g)
    xf = xq.q.astype(jnp.bfloat16).reshape(*lead, k // g, g)
    # exponent-only rescale of the operand: exact in bf16 (po2)
    xf = (xf * ss[..., None].astype(jnp.bfloat16)).reshape(*lead, k)
    acc = mm(xf, wq.q, out_dtype=jnp.float32)
    y = acc * (xq.s * wq.s)                                    # epilogue
    return y.astype(out_dtype)


def group_gemm(xq: PerGroupQ, wq: PerGroupQ | PerTensorQ,
               out_dtype=jnp.bfloat16) -> jax.Array:
    """COAT-style GEMM (paper Fig 3a): per-group f32 rescale of every
    partial sum along K — the in-loop dequantization MOSS removes."""
    from .runtime_flags import einsum

    *lead, k = xq.q.shape
    g = k // xq.s.shape[-1]
    xf = xq.q.reshape(*lead, k // g, g)
    if isinstance(wq, PerTensorQ):
        w_s = jnp.broadcast_to(wq.s, (k // g, wq.q.shape[-1]))
    else:
        w_s = wq.s  # (K/g, N)
    wf = wq.q.reshape(k // g, g, -1)
    # partial sums per K-group, each rescaled in f32 then accumulated:
    partial = einsum("...gk,gkn->...gn", xf, wf, out_dtype=jnp.float32)
    scaled = partial * (xq.s[..., None] * w_s[(None,) * len(lead)])
    y = jnp.sum(scaled, axis=-2)
    return y.astype(out_dtype)


def pt_gemm(xq: PerTensorQ, wq: PerTensorQ, out_dtype=jnp.bfloat16) -> jax.Array:
    """TE-style per-tensor GEMM: epilogue-only dequant."""
    from .runtime_flags import mm

    acc = mm(xq.q, wq.q, out_dtype=jnp.float32)
    return (acc * (xq.s * wq.s)).astype(out_dtype)


# ---------------------------------------------------------------------------
# Fidelity metric (paper Eq. 4)
# ---------------------------------------------------------------------------


def snr_db(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    """Quantization signal-to-noise ratio in dB."""
    x = x.astype(jnp.float32)
    noise = x_hat.astype(jnp.float32) - x
    p_sig = jnp.mean(x * x)
    p_noise = jnp.maximum(jnp.mean(noise * noise), TINY)
    return 10.0 * jnp.log10(p_sig / p_noise)


def scheme_snr(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """SNR of quantize→dequantize under the configured scheme."""
    if cfg.mode == "per_tensor":
        dq = quant_per_tensor(x, cfg.fwd_format).dequant()
    elif cfg.mode == "per_group":
        dq = quant_per_group(x, cfg.group_size, cfg.fwd_format).dequant()
    elif cfg.mode == "moss":
        dq = quant_mx(x, cfg.micro_group, cfg.fwd_format).dequant()
    else:
        dq = x.astype(jnp.bfloat16).astype(jnp.float32)
    return snr_db(x, dq)


# ---------------------------------------------------------------------------
# Paper-model (Theorem 1) SNR: the paper analyzes quantization noise as
# *uniform in [-s/2, s/2]* per group — an absolute-noise (fixed-point)
# model, under which noise power is s²/12 regardless of the values.  True
# float8 noise is relative for in-range values (power-of-two rescaling is
# *exact*), so the measured-SNR ordering only separates in the
# saturation/underflow regimes; the paper's Eq. (5)-(7) ordering, however,
# holds for any tensor with within-group structure.  Both views are
# implemented; EXPERIMENTS.md discusses the distinction.
# ---------------------------------------------------------------------------


def _uniform_model_snr(x: jax.Array, noise_power: jax.Array) -> jax.Array:
    sigma2 = jnp.mean(jnp.square(x.astype(jnp.float32)))
    return 10.0 * jnp.log10(sigma2 / jnp.maximum(noise_power, TINY))


def model_snr_per_tensor(x: jax.Array, fmt: FP8Format = "e4m3") -> jax.Array:
    """Paper Eq. (5): noise = s²/12 with s = max|X|/Δmax."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32))) / fp8_max(fmt)
    return _uniform_model_snr(x, s * s / 12.0)


def model_snr_per_group(x: jax.Array, group: int = 128,
                        fmt: FP8Format = "e4m3") -> jax.Array:
    """Paper Eq. (6): noise = mean_g s_g²/12."""
    *lead, k = x.shape
    xg = x.astype(jnp.float32).reshape(*lead, k // group, group)
    s_g = jnp.max(jnp.abs(xg), axis=-1) / fp8_max(fmt)
    return _uniform_model_snr(x, jnp.mean(s_g * s_g) / 12.0)


def model_snr_moss(x: jax.Array, micro_group: int = 32,
                   fmt: FP8Format = "e4m3") -> jax.Array:
    """Paper Eq. (7): noise = mean_g (s·ss_g)²/12 with E8M0 ss_g."""
    q = quant_mx(x, micro_group, fmt)
    eff = q.s * e8m0_decode(q.sexp)
    return _uniform_model_snr(x, jnp.mean(eff * eff) / 12.0)
