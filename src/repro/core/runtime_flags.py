"""Backend-dependent execution flags.

The CPU backend *compiles* bf16 x bf16 -> f32 dots but cannot *execute*
them (DotThunk limitation).  Because every operand we feed a GEMM is an
exact bf16 value (fp8 casts and power-of-two subscales are exact in
bf16), computing with f32 operands + f32 accumulation is bit-identical
to bf16 operands + f32 accumulation.  So:

  - TPU (and dry-run lowering, which never executes): bf16 operands —
    the real MXU operand dtype, and the dtype whose bytes the roofline
    memory term should count.
  - CPU execution (tests/benchmarks): f32 operands.

``force_bf16_operands()`` is flipped on by launch/dryrun.py before
lowering so the compiled HLO reflects TPU operand widths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_FORCE_BF16 = False


def force_bf16_operands(value: bool = True) -> None:
    global _FORCE_BF16
    _FORCE_BF16 = value


def mm_operand_dtype():
    if _FORCE_BF16 or jax.default_backend() == "tpu":
        return jnp.bfloat16
    return jnp.float32


def mm(a, b, out_dtype=jnp.float32):
    """Portable matmul with bf16-operand semantics, f32 accumulation."""
    dt = mm_operand_dtype()
    a = a.astype(jnp.bfloat16).astype(dt)
    b = b.astype(jnp.bfloat16).astype(dt)
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def einsum(spec, *args, out_dtype=jnp.float32):
    dt = mm_operand_dtype()
    args = [a.astype(jnp.bfloat16).astype(dt) for a in args]
    return jnp.einsum(spec, *args,
                      preferred_element_type=jnp.float32).astype(out_dtype)
