"""Backend-dependent execution flags.

The CPU backend *compiles* bf16 x bf16 -> f32 dots but cannot *execute*
them (DotThunk limitation).  Because every operand we feed a GEMM is an
exact bf16 value (fp8 casts and power-of-two subscales are exact in
bf16), computing with f32 operands + f32 accumulation is bit-identical
to bf16 operands + f32 accumulation.  So:

  - TPU (and dry-run lowering, which never executes): bf16 operands —
    the real MXU operand dtype, and the dtype whose bytes the roofline
    memory term should count.
  - CPU execution (tests/benchmarks): f32 operands.

``force_bf16_operands()`` is flipped on by launch/dryrun.py before
lowering so the compiled HLO reflects TPU operand widths.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

_FORCE_BF16 = False

# Kernel-dispatch backends (see repro.kernels.dispatch):
#   "pallas"    — Pallas-native kernels (TPU)
#   "interpret" — the same Pallas kernels under the interpreter (CPU;
#                 slow — parity tests and kernel-path debugging)
#   "ref"       — the pure-jnp semantic reference in repro.core.quant
KERNEL_BACKENDS = ("pallas", "interpret", "ref")


def kernel_backend() -> str:
    """Active kernel backend: ``REPRO_KERNELS`` env override, else
    Pallas-native on TPU and the jnp reference elsewhere."""
    env = os.environ.get("REPRO_KERNELS", "").strip()
    if env:
        if env not in KERNEL_BACKENDS:
            raise ValueError(
                f"REPRO_KERNELS={env!r}: expected one of {KERNEL_BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# MoE expert-GEMM paths (see repro.models.moe):
#   "grouped" — one ragged grouped-expert kernel per GEMM (3 launches +
#               1 amax reduction per MoE block; the default)
#   "vmapped" — legacy jax.vmap over per-expert qlinear (3·E launches +
#               E reductions; kept for A/B benchmarking)
MOE_EXPERT_PATHS = ("grouped", "vmapped")


def moe_expert_path() -> str:
    """Active MoE expert path: ``REPRO_MOE_EXPERTS`` env override, else
    the grouped kernel.  Applies to moss/bf16 train/prefill (bf16
    grouped is bitwise identical to vmapped); the per-tensor/per-group
    baselines and the decode path always use the vmapped experts."""
    env = os.environ.get("REPRO_MOE_EXPERTS", "").strip()
    if env:
        if env not in MOE_EXPERT_PATHS:
            raise ValueError(
                f"REPRO_MOE_EXPERTS={env!r}: expected one of "
                f"{MOE_EXPERT_PATHS}")
        return env
    return "grouped"


# Serving weight pre-quantization (see repro.train.steps.
# prequantize_params and launch/serve.py): quantize the whole weight
# stack to fp8 payloads + scales ONCE at Server build time so the
# decode/prefill graphs contain no weight quantize or max-reduction
# ops.  REPRO_SERVE_PREQUANT=0 is the escape hatch back to in-graph
# quantization (the training-eval behavior).
def serve_prequant() -> bool:
    """Whether the serving path pre-quantizes weights at build time."""
    return os.environ.get("REPRO_SERVE_PREQUANT", "1").strip() != "0"


# Delayed activation scales for serving (see repro.core.actscale and
# docs/serving.md): Engine/Server calibrate per-site activation scales
# with one eager forward at build and the decode/prefill graphs consume
# them instead of measuring per-step amaxes — zero quantization
# reductions in the decode jaxpr (core.introspect.
# count_quant_reductions).  REPRO_SERVE_DELAYED_ACT=0 is the escape
# hatch back to just-in-time activation scaling (bitwise the
# pre-delayed graphs).
def serve_delayed_act() -> bool:
    """Whether serving consumes calibrated (delayed) activation scales
    instead of in-graph per-step amax reductions."""
    return os.environ.get("REPRO_SERVE_DELAYED_ACT", "1").strip() != "0"


# Paged continuous-batching serving (see repro.serving and
# launch/serve.py): the paged engine (per-slot lengths, block-table
# page accounting, scheduler with TTFT/TPOT metrics, retirement of
# finished slots from the decode batch) is the serving default.
# REPRO_SERVE_PAGED=0 falls back to the legacy contiguous-ring
# Server: one fixed-B slot cache, FIFO refill, no page accounting
# (still per-slot-length-correct — docs/continuous-batching.md).
def serve_paged() -> bool:
    """Whether launch/serve.py drives the paged serving engine."""
    return os.environ.get("REPRO_SERVE_PAGED", "1").strip() != "0"


# Paged-engine page placement (see repro.serving.paged_cache and
# docs/paged-attention.md):
#   "float"    — true floating pages: one global page pool, per-slot
#                block tables gathered inside the decode kernel,
#                free-list allocator with refcounts + copy-on-write
#                prefix sharing (the default where supported)
#   "identity" — PR5 behavior: block tables are identity-mapped onto
#                per-slot contiguous cache rows (A/B fallback; also
#                what unsupported families — MLA/ssm/hybrid/windowed —
#                silently take)
PAGED_PLACEMENTS = ("float", "identity")


def paged_placement() -> str:
    """Active page placement: ``REPRO_PAGED_PLACEMENT`` env override,
    else floating pages."""
    env = os.environ.get("REPRO_PAGED_PLACEMENT", "").strip()
    if env:
        if env not in PAGED_PLACEMENTS:
            raise ValueError(
                f"REPRO_PAGED_PLACEMENT={env!r}: expected one of "
                f"{PAGED_PLACEMENTS}")
        return env
    return "float"


# Scheduler v2 (see repro.serving.engine and
# docs/continuous-batching.md): chunked prefill interleaves fixed-size
# prompt chunks with decode steps through ONE compiled mixed-step
# shape (no per-bucket prefill compiles, no B=1 prefill stall, and
# prefix-hit suffixes prefill at an offset instead of replaying
# token-by-token).  REPRO_CHUNKED_PREFILL=0 falls back to the v1
# whole-prompt B=1 prefill (prefix hits are then served cold).
def chunked_prefill() -> bool:
    """Whether the paged engine prefills prompts in fixed-size chunks
    interleaved with decode steps (Scheduler v2)."""
    return os.environ.get("REPRO_CHUNKED_PREFILL", "1").strip() != "0"


# Preemption + usage-based admission (float placement only): victims'
# pages are copied to a host-side store and freed, so `PageAllocator`
# admission runs on actual usage plus a small headroom instead of
# worst-case prompt+max_new reservations.  REPRO_PREEMPTION=0 keeps
# the v1 reservation-based admission (nothing is ever swapped out).
def serve_preemption() -> bool:
    """Whether the paged engine may preempt running requests to host
    and admit against actual page usage (Scheduler v2)."""
    return os.environ.get("REPRO_PREEMPTION", "1").strip() != "0"


def serve_prefix_cache() -> bool:
    """Whether the floating-page engine hashes page-aligned prompt
    prefixes and maps hits copy-on-write onto the shared physical
    pages (docs/paged-attention.md).  REPRO_PREFIX_CACHE=0 disables
    (every request prefills cold); meaningless under identity
    placement."""
    return os.environ.get("REPRO_PREFIX_CACHE", "1").strip() != "0"


# Speculative multi-token decode (docs/speculative-decoding.md): the
# engine proposes k-1 draft tokens per step (greedy n-gram lookup by
# default, or an injected draft model), verifies all k in ONE forward
# over the fp8 KV cache and commits the longest matching prefix — the
# greedy output is token-for-token identical to plain decode, it just
# arrives in fewer cache reads.  Default OFF: the win depends on the
# trace (repetitive suffixes accept long drafts; adversarial text
# accepts none), so it is an opt-in — REPRO_SPEC_DECODE=1 or
# Engine(spec_decode=True).
def spec_decode() -> bool:
    """Whether the serving engine runs speculative verify steps in the
    decode phase (chunked v2 scheduler only)."""
    return os.environ.get("REPRO_SPEC_DECODE", "0").strip() == "1"


# Observability (see repro.obs and docs/observability.md).  Both
# gates default OFF, and off is FREE: the serving step functions are
# built without any telemetry code paths, so the decode/verify jaxprs
# stay byte-identical to an obs-free build (tests/test_obs.py).
def quant_health() -> bool:
    """Whether serving steps additionally return per-site fp8
    quantization-health statistics (saturation / underflow / ActScale
    drift — repro.obs.quant_health).  Opt-in: REPRO_QUANT_HEALTH=1."""
    return os.environ.get("REPRO_QUANT_HEALTH", "0").strip() == "1"


def quant_health_every() -> int:
    """REPRO_QUANT_HEALTH_EVERY: sample the health-instrumented step
    variant every Nth engine step call (default 16, min 1).  The other
    steps run the plain graphs, bounding telemetry overhead to
    ~cost/N; drift moves over thousands of steps, so sparse sampling
    loses no signal."""
    env = os.environ.get("REPRO_QUANT_HEALTH_EVERY", "").strip()
    try:
        return max(1, int(env)) if env else 16
    except ValueError:
        return 16


def trace_path() -> str | None:
    """REPRO_TRACE: the Chrome-trace output path, or None (tracing
    off).  Read once by ``repro.obs.trace.get_tracer``."""
    env = os.environ.get("REPRO_TRACE", "").strip()
    return env or None


# Decode-attention path (see repro.models.attention._decode_attention
# and repro.kernels.dispatch.decode_attention):
#   "kernel" — route through the kernel dispatch: the fused Pallas
#              decode kernel on pallas/interpret backends, the einsum
#              oracle on the ref backend (the default)
#   "einsum" — pin the scale-folding einsum path regardless of the
#              kernel backend (A/B fallback; bitwise-identical to
#              "kernel" under the ref backend)
DECODE_ATTN_PATHS = ("kernel", "einsum")


def decode_attn_path() -> str:
    """Active decode-attention path: ``REPRO_DECODE_ATTN`` env
    override, else the fused kernel through the dispatch layer."""
    env = os.environ.get("REPRO_DECODE_ATTN", "").strip()
    if env:
        if env not in DECODE_ATTN_PATHS:
            raise ValueError(
                f"REPRO_DECODE_ATTN={env!r}: expected one of "
                f"{DECODE_ATTN_PATHS}")
        return env
    return "kernel"


# KV-cache storage dtype (see repro.models.attention.resolve_kv_cache_
# dtype): per-arch configs default to "fp8" for the decode-bound
# shapes; REPRO_KV_CACHE overrides every config in both directions.
KV_CACHE_DTYPES = ("bf16", "fp8")


def kv_cache_override() -> str | None:
    """``REPRO_KV_CACHE`` env override for the KV-cache storage dtype,
    or None to use the per-arch config value."""
    env = os.environ.get("REPRO_KV_CACHE", "").strip()
    if not env:
        return None
    if env not in KV_CACHE_DTYPES:
        raise ValueError(
            f"REPRO_KV_CACHE={env!r}: expected one of {KV_CACHE_DTYPES}")
    return env


def force_bf16_operands(value: bool = True) -> None:
    global _FORCE_BF16
    _FORCE_BF16 = value


def mm_operand_dtype():
    if _FORCE_BF16 or jax.default_backend() == "tpu":
        return jnp.bfloat16
    return jnp.float32


def mm(a, b, out_dtype=jnp.float32):
    """Portable matmul with bf16-operand semantics, f32 accumulation."""
    dt = mm_operand_dtype()
    a = a.astype(jnp.bfloat16).astype(dt)
    b = b.astype(jnp.bfloat16).astype(dt)
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def einsum(spec, *args, out_dtype=jnp.float32):
    dt = mm_operand_dtype()
    args = [a.astype(jnp.bfloat16).astype(dt) for a in args]
    return jnp.einsum(spec, *args,
                      preferred_element_type=jnp.float32).astype(out_dtype)
