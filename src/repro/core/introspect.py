"""Jaxpr introspection: structural op counts for the serving contract.

The pre-quantized serving path (docs/serving.md) promises that the
decode graph contains **zero weight quantize / weight max-reduction
ops**, and the fused decode-attention path
(docs/decode-attention.md) that it contains **zero cache-sized
dequantization upcasts or dots** — structural properties, checked
directly on the jaxpr rather than inferred from wall clock (which on
CPU measures fp8 emulation).  Used by ``tests/test_serving.py``,
``tests/test_decode_attn.py`` and ``benchmarks/run.py``'s
``BENCH_serve.json`` / ``BENCH_decode.json`` rows.
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp

from repro.compat.jaxapi import ClosedJaxpr, Jaxpr


def iter_eqns(jaxpr, skip_into: tuple[str, ...] = ()) -> Iterator:
    """Depth-first over every equation of a (Closed)Jaxpr, descending
    into sub-jaxprs (scan/while bodies, cond branches, pjit calls,
    custom_vjp calls) via the eqn params.  Primitives named in
    ``skip_into`` are yielded but NOT descended into — pass
    ``("pallas_call",)`` to count XLA-level (HBM-visible) ops only,
    excluding arithmetic that happens on VMEM blocks inside a kernel
    body."""
    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name in skip_into:
            continue
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from iter_eqns(sub, skip_into)


def _sub_jaxprs(val):
    if isinstance(val, (ClosedJaxpr, Jaxpr)):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _sub_jaxprs(v)
    elif callable(val) and hasattr(val, "jaxpr"):   # pjit's WrappedFun-likes
        sub = getattr(val, "jaxpr")
        if isinstance(sub, (ClosedJaxpr, Jaxpr)):
            yield sub


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` (e.g. "reduce_max") anywhere in
    the jaxpr, sub-jaxprs included.  NOTE: an op inside a scan body is
    counted once, not once per trip — counts are *structural*."""
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == name)


def count_reduce_max_over(jaxpr, sizes: set[int]) -> int:
    """reduce_max equations whose operand element count is in ``sizes``
    — with the quantized weight-slice sizes this counts *weight* amax
    reductions (the in-graph scale computation pre-quantization
    removes)."""
    n = 0
    for e in iter_eqns(jaxpr):
        if e.primitive.name != "reduce_max":
            continue
        op_size = 1
        for d in e.invars[0].aval.shape:
            op_size *= d
        if op_size in sizes:
            n += 1
    return n


_FP8_DTYPES = (jnp.float8_e4m3fn, jnp.float8_e5m2)


def count_fp8_casts(jaxpr, sizes: set[int] | None = None) -> int:
    """convert_element_type-to-fp8 equations, optionally restricted to
    operands whose element count is in ``sizes`` — pass the quantized
    weight-slice sizes (``weight_slice_sizes``) to count *weight*
    quantizations only (activation casts have per-token-batch sizes,
    disjoint from weight sizes for any realistic config)."""
    n = 0
    for e in iter_eqns(jaxpr):
        if e.primitive.name != "convert_element_type":
            continue
        if e.params.get("new_dtype") not in _FP8_DTYPES:
            continue
        op_size = 1
        for d in e.invars[0].aval.shape:
            op_size *= d
        if sizes is None or op_size in sizes:
            n += 1
    return n


def _op_size(var) -> int:
    n = 1
    for d in var.aval.shape:
        n *= d
    return n


def count_fp8_dequant_upcasts(jaxpr, sizes: set[int]) -> int:
    """convert_element_type equations FROM an fp8 dtype to a wider one
    whose operand element count is in ``sizes`` — with the KV-cache
    slice sizes (``kv_cache_slice_sizes``) this counts decode-attention
    *dequantizations* of the cache payload: the scale-folding einsum
    path upcasts the whole e4m3 K and V to feed the MXU (2 per layer),
    the fused kernel reads the payload directly (0).  pallas_call
    bodies are not descended into — in-kernel upcasts act on VMEM
    blocks, not HBM-resident tensors."""
    n = 0
    for e in iter_eqns(jaxpr, skip_into=("pallas_call",)):
        if e.primitive.name != "convert_element_type":
            continue
        if e.invars[0].aval.dtype not in _FP8_DTYPES:
            continue
        if e.params.get("new_dtype") in _FP8_DTYPES:
            continue
        if _op_size(e.invars[0]) in sizes:
            n += 1
    return n


# Primitives a quantizer's *scale arithmetic* may route through between
# an amax reduction and the final fp8 cast: abs/max chains, the
# FP8_MAX / TINY normalization, E8M0 encode (log2/ceil/clip) and decode
# (bit shifts + bitcast), the zero-denominator guard (comparisons +
# select_n), and shape plumbing.  Deliberately EXCLUDES ``exp`` and
# ``dot_general`` so a softmax's max-subtraction chain (max → sub → exp)
# dies at the exp and never reaches a downstream quantize through the
# attention output (tests/test_introspect.py's negative controls).
_SCALE_CHAIN_PRIMS = frozenset({
    "abs", "max", "min", "div", "mul", "sub", "add", "neg", "sign",
    "reduce_max", "reduce_min", "reshape", "broadcast_in_dim", "squeeze",
    "convert_element_type", "clamp", "select_n", "gt", "lt", "ge", "le",
    "eq", "ne", "log", "log2", "ceil", "floor", "round", "exp2",
    "integer_pow", "pow", "rsqrt", "sqrt", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "or", "and", "xor",
    "bitcast_convert_type", "transpose", "slice", "dynamic_slice",
    "stop_gradient", "concatenate", "copy", "is_finite",
})


def count_quant_reductions(jaxpr) -> int:
    """max/abs-reduction equations whose result *feeds a quantize* — a
    ``convert_element_type`` to an fp8 dtype — through scale arithmetic
    only.

    This is the structural definition of "the graph computes a
    quantization scale at runtime": every just-in-time quantizer
    (per-tensor, per-group, MOSS two-level, KV-cache write) starts with
    a ``reduce_max`` over ``|x|`` and ends in an fp8 cast, with nothing
    between them but scale arithmetic (``_SCALE_CHAIN_PRIMS``).  The
    delayed/predicted-scale serving path (docs/serving.md) consumes
    cached scales instead, so its decode jaxpr counts **zero** — while
    a softmax's max (max → sub → **exp**) or a masking max is never
    miscounted: the allowlisted chain stops at the first non-scale
    primitive.

    Reachability FOLLOWS CALL BOUNDARIES: the fp8 cast often sits in a
    ``pjit`` sub-jaxpr of the scan/custom_vjp body holding the
    reduction, so taint maps positionally through call-like eqns
    (eqn invar i ↔ body invar i, eqn outvar j ↔ body outvar j — exact
    for pjit / scan / custom_vjp / remat; ``cond`` shifts by the
    predicate).  Counts are structural — a reduction inside a scan
    body counts once, not once per trip."""
    total = 0
    seen: set[int] = set()

    def walk(jx):
        nonlocal total
        if isinstance(jx, ClosedJaxpr):
            jx = jx.jaxpr
        if id(jx) in seen:
            return
        seen.add(id(jx))
        for eqn in jx.eqns:
            if (eqn.primitive.name == "reduce_max"
                    and _taint_flow(jx, {id(v) for v in eqn.outvars})[0]):
                total += 1
            for val in eqn.params.values():
                for sub in _sub_jaxprs(val):
                    walk(sub)

    walk(jaxpr)
    return total


def _is_var(v) -> bool:
    return hasattr(v, "aval") and not hasattr(v, "val")  # not a Literal


def _taint_flow(jx, start_ids=frozenset(), in_positions=()):
    """Propagate taint forward through ONE jaxpr (eqns are in
    topological order) and into call-like sub-jaxprs by positional
    invar/outvar mapping.  Returns ``(reached_fp8_cast,
    tainted_outvar_positions)``."""
    if isinstance(jx, ClosedJaxpr):
        jx = jx.jaxpr
    tainted = set(start_ids)
    for i in in_positions:
        if i < len(jx.invars):
            tainted.add(id(jx.invars[i]))
    found = False
    for eqn in jx.eqns:
        tin = [i for i, v in enumerate(eqn.invars)
               if _is_var(v) and id(v) in tainted]
        if not tin:
            continue
        name = eqn.primitive.name
        subs = [s for val in eqn.params.values() for s in _sub_jaxprs(val)]
        if subs:
            off = 1 if name == "cond" else 0
            pos = [i - off for i in tin if i >= off]
            for sub in subs:
                f, tout = _taint_flow(sub, in_positions=pos)
                found = found or f
                for o in tout:
                    if o < len(eqn.outvars):
                        tainted.add(id(eqn.outvars[o]))
            continue
        if (name == "convert_element_type"
                and eqn.params.get("new_dtype") in _FP8_DTYPES):
            found = True
            continue
        if name in _SCALE_CHAIN_PRIMS:
            for v in eqn.outvars:
                tainted.add(id(v))
        # else: chain dies at a non-scale primitive
    tout = {i for i, v in enumerate(jx.outvars)
            if _is_var(v) and id(v) in tainted}
    return found, tout


def count_dot_general_over(jaxpr, sizes: set[int]) -> int:
    """dot_general equations with an operand whose element count is in
    ``sizes`` — with the KV-cache slice sizes this counts the einsum
    decode path's score and combine contractions against the cache
    (2 per layer; the fused kernel leaves 0 at the XLA level — its
    in-kernel dots act on blocks and are excluded via skip_into)."""
    n = 0
    for e in iter_eqns(jaxpr, skip_into=("pallas_call",)):
        if e.primitive.name != "dot_general":
            continue
        if any(_op_size(v) in sizes for v in e.invars):
            n += 1
    return n


def kv_cache_slice_sizes(cfg, batch: int, max_len: int) -> set[int]:
    """Element count of ONE layer's K (or V) cache payload — the shape
    the scan-over-layers decode body sees, i.e. the operand size of a
    cache dequant upcast / cache dot in the decode jaxpr.  Callers must
    pick test shapes where this doesn't collide with activation or
    weight slice sizes (trivially true for the smoke configs)."""
    from repro.models.attention import cache_len

    c = cache_len(cfg, max_len)
    return {batch * cfg.n_kv * c * cfg.head_dim}


def weight_slice_sizes(cfg) -> set[int]:
    """Element counts of every quantized weight's per-(layer, expert)
    slice — the shapes the scan-over-layers forward quantizes (and the
    shapes a weight-quantize cast would have in the decode jaxpr)."""
    from repro.models.layers import PDef, is_pdef
    from repro.models.transformer import model_defs
    from repro.train.steps import _scale_dims

    defs = model_defs(cfg)
    sdims = _scale_dims(defs)
    sizes: set[int] = set()

    def add(d: PDef, nd: int):
        n = 1
        for dim in d.shape[nd:]:
            n *= dim
        if d.quantized:
            sizes.add(n)

    jax.tree.map(add, defs, sdims, is_leaf=is_pdef)
    return sizes
