"""Jaxpr introspection: structural op counts for the serving contract.

The pre-quantized serving path (docs/serving.md) promises that the
decode graph contains **zero weight quantize / weight max-reduction
ops** — a structural property, checked directly on the jaxpr rather
than inferred from wall clock (which on CPU measures fp8 emulation).
Used by ``tests/test_serving.py`` and ``benchmarks/run.py``'s
``BENCH_serve.json`` rows.
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp

from repro.compat.jaxapi import ClosedJaxpr, Jaxpr


def iter_eqns(jaxpr) -> Iterator:
    """Depth-first over every equation of a (Closed)Jaxpr, descending
    into sub-jaxprs (scan/while bodies, cond branches, pjit calls,
    custom_vjp calls) via the eqn params."""
    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from iter_eqns(sub)


def _sub_jaxprs(val):
    if isinstance(val, (ClosedJaxpr, Jaxpr)):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _sub_jaxprs(v)
    elif callable(val) and hasattr(val, "jaxpr"):   # pjit's WrappedFun-likes
        sub = getattr(val, "jaxpr")
        if isinstance(sub, (ClosedJaxpr, Jaxpr)):
            yield sub


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` (e.g. "reduce_max") anywhere in
    the jaxpr, sub-jaxprs included.  NOTE: an op inside a scan body is
    counted once, not once per trip — counts are *structural*."""
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == name)


def count_reduce_max_over(jaxpr, sizes: set[int]) -> int:
    """reduce_max equations whose operand element count is in ``sizes``
    — with the quantized weight-slice sizes this counts *weight* amax
    reductions (the in-graph scale computation pre-quantization
    removes)."""
    n = 0
    for e in iter_eqns(jaxpr):
        if e.primitive.name != "reduce_max":
            continue
        op_size = 1
        for d in e.invars[0].aval.shape:
            op_size *= d
        if op_size in sizes:
            n += 1
    return n


_FP8_DTYPES = (jnp.float8_e4m3fn, jnp.float8_e5m2)


def count_fp8_casts(jaxpr, sizes: set[int] | None = None) -> int:
    """convert_element_type-to-fp8 equations, optionally restricted to
    operands whose element count is in ``sizes`` — pass the quantized
    weight-slice sizes (``weight_slice_sizes``) to count *weight*
    quantizations only (activation casts have per-token-batch sizes,
    disjoint from weight sizes for any realistic config)."""
    n = 0
    for e in iter_eqns(jaxpr):
        if e.primitive.name != "convert_element_type":
            continue
        if e.params.get("new_dtype") not in _FP8_DTYPES:
            continue
        op_size = 1
        for d in e.invars[0].aval.shape:
            op_size *= d
        if sizes is None or op_size in sizes:
            n += 1
    return n


def weight_slice_sizes(cfg) -> set[int]:
    """Element counts of every quantized weight's per-(layer, expert)
    slice — the shapes the scan-over-layers forward quantizes (and the
    shapes a weight-quantize cast would have in the decode jaxpr)."""
    from repro.models.layers import PDef, is_pdef
    from repro.models.transformer import model_defs
    from repro.train.steps import _scale_dims

    defs = model_defs(cfg)
    sdims = _scale_dims(defs)
    sizes: set[int] = set()

    def add(d: PDef, nd: int):
        n = 1
        for dim in d.shape[nd:]:
            n *= dim
        if d.quantized:
            sizes.add(n)

    jax.tree.map(add, defs, sdims, is_leaf=is_pdef)
    return sizes
