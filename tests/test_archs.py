"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes + no NaNs (harness deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ASSIGNED, get_config
from repro.core.formats import MOSS_CONFIG
from repro.models.layers import init_tree, quant_mask_tree, wrap_qt_nojit
from repro.models.transformer import forward, model_defs
from repro.train.steps import (
    TrainHParams,
    init_train_state,
    make_train_step,
)

B, S = 2, 64


def _batch(cfg, key=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key),
                                          (B, S), 0, cfg.vocab)}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, S, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    batch["labels"] = batch["tokens"]
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    batch = _batch(cfg)
    logits, _, aux = forward(cfg, MOSS_CONFIG, qp, batch, mode="train")
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))
    if cfg.n_experts:
        assert float(aux) > 0.0      # load-balance loss active


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, hp))
    state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state.step) == 1
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "rwkv6-3b",
                                  "recurrentgemma-2b",
                                  "deepseek-v2-lite-16b"])
def test_loss_decreases_on_repeated_batch(arch):
    cfg = get_config(arch, smoke=True)
    hp = TrainHParams(peak_lr=2e-3, warmup_steps=2, total_steps=30)
    state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, hp))
    batch = _batch(cfg)
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_shape_applicability_matrix():
    """long_500k runs exactly on the sub-quadratic archs."""
    runnable = {a: [s for s in SHAPES
                    if shape_applicable(get_config(a), SHAPES[s])[0]]
                for a in ASSIGNED}
    subq = {"rwkv6-3b", "recurrentgemma-2b", "h2o-danube-3-4b"}
    for a in ASSIGNED:
        assert "train_4k" in runnable[a]
        assert "prefill_32k" in runnable[a]
        assert "decode_32k" in runnable[a]
        assert ("long_500k" in runnable[a]) == (a in subq), a
    total = sum(len(v) for v in runnable.values())
    assert total == 33       # 40 cells - 7 documented skips


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_microbatched_step_matches_full(arch):
    """Gradient accumulation is loss-equivalent to the full batch.
    Run in bf16 so per-microbatch quantization scales (which legally
    differ from full-batch scales) don't blur the comparison."""
    from repro.core.formats import BF16_CONFIG

    cfg = get_config(arch, smoke=True).replace(quant=BF16_CONFIG)
    hp1 = TrainHParams(peak_lr=0.0, warmup_steps=1, total_steps=2,
                       microbatches=1, grad_clip=1e9)
    hp2 = hp1._replace(microbatches=2)
    batch = _batch(cfg)
    s1 = init_train_state(cfg, hp1, jax.random.PRNGKey(0))
    s2 = init_train_state(cfg, hp2, jax.random.PRNGKey(0))
    _, m1 = jax.jit(make_train_step(cfg, hp1))(s1, batch)
    _, m2 = jax.jit(make_train_step(cfg, hp2))(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.02
