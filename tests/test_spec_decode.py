"""Speculative multi-token decode exactness harness
(docs/speculative-decoding.md).

THE contract: greedy speculative output is token-for-token identical
to plain decode — for every draft length k, every draft source
(oracle, adversarial, n-gram), fp8 AND bf16 caches, ref AND interpret
kernel backends, float AND identity page placements, through
mid-stream rejections, EOS inside a draft window and mixed-depth
batches.  The draft source only changes how many tokens each cache
read commits, never which tokens.

Layers under test, innermost out:

- kernel: the batched-query (q_len > 1) in-step causal mask — each
  draft row of one 5-D launch is BITWISE the 4-D single-query launch
  at that draft's own validity window (contiguous and paged);
- step: ``make_verify_step``'s k logit rows are BITWISE the k
  sequential ``make_decode_step`` calls they replace;
- engine: end-to-end token parity vs the plain-decode engine, plus
  accept-rate bookkeeping;
- jaxpr: the (B, k) verify graph keeps the fused-kernel serving
  contract — ZERO cache-sized fp8 dequant upcasts, ZERO cache-sized
  dot_generals, and zero quantization amax reductions beyond the two
  unavoidable K/V storage-write amaxes on an fp8 cache (zero outright
  on bf16).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.formats import BF16_CONFIG
from repro.kernels import dispatch
from repro.models import attention as A
from repro.models.layers import init_tree
from repro.models.transformer import model_defs, spec_verify_supported
from repro.serving import Engine, ModelDraft, NgramDraft, Request
from repro.serving.spec import DraftSource
from repro.train.steps import (
    make_decode_step,
    make_prefill_step,
    make_verify_step,
    prequantize_params,
)

MAX_LEN = 64


def _cfg(kv_dtype="fp8"):
    return get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype=kv_dtype)


def _params(cfg):
    return init_tree(model_defs(cfg), jax.random.PRNGKey(0))


def _requests(cfg, lens, max_new=10, seed=0, eos=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, spec in enumerate(lens):
        n, mn = spec if isinstance(spec, tuple) else (spec, max_new)
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, size=n,
                                       dtype=np.int32),
            max_new=mn, eos_id=eos))
    return reqs


def _serve(cfg, params, lens, *, spec, max_new=10, eos=None, **kw):
    eng = Engine(cfg, params, num_slots=3, max_len=MAX_LEN,
                 spec_decode=spec, **kw)
    reqs = _requests(cfg, lens, max_new=max_new, eos=eos)
    eng.run(reqs, log=None)
    return {r.rid: list(r.out) for r in reqs}, eng


class Oracle:
    """Proposes the exact continuation recorded from a baseline run —
    maximal acceptance, the accepted-tokens/step upper bound."""

    def __init__(self, truth):
        self.truth = truth

    def propose(self, req, k):
        t = self.truth[req.rid]
        return t[len(req.out):len(req.out) + k]


class Adversarial:
    """Always-wrong proposals — every draft must be rejected and the
    engine must still emit exactly the plain-decode stream (one
    correction token per verify step)."""

    def __init__(self, truth):
        self.truth = truth

    def propose(self, req, k):
        t = self.truth[req.rid]
        nxt = t[len(req.out):len(req.out) + k]
        return [(x + 1) % 500 for x in nxt] or [0]


class HalfOracle:
    """Right for the first ``good`` drafts of every window, wrong
    after — forces a MID-STREAM rejection inside every verify step
    (partial accept + truncation + correction)."""

    def __init__(self, truth, good=1):
        self.truth = truth
        self.good = good

    def propose(self, req, k):
        t = self.truth[req.rid]
        nxt = list(t[len(req.out):len(req.out) + k])
        for j in range(self.good, len(nxt)):
            nxt[j] = (nxt[j] + 1) % 500
        return nxt


def _truth(cfg, params, lens, max_new=10, eos=None):
    out, _ = _serve(cfg, params, lens, spec=False, max_new=max_new,
                    eos=eos)
    return out


# ---------------------------------------------------------------------------
# Engine-level token parity — the acceptance contract
# ---------------------------------------------------------------------------


MIXED_LENS = [5, 9, 17]          # straddle chunk/page boundaries


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_token_parity_all_draft_sources(kv_dtype, k, monkeypatch):
    """Every draft source — full-accept oracle, always-rejected
    adversarial, per-window partial accept, and the real n-gram
    lookup — produces token-for-token the plain-decode stream, for
    k in {1, 2, 4} (k=1 exercises the fall-back-to-plain clamp)."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    cfg = _cfg(kv_dtype)
    params = _params(cfg)
    truth = _truth(cfg, params, MIXED_LENS)
    sources = [Oracle(truth), Adversarial(truth),
               HalfOracle(truth, good=1), NgramDraft()]
    for draft in sources:
        got, eng = _serve(cfg, params, MIXED_LENS, spec=True,
                          draft=draft, spec_k=k)
        assert got == truth, (kv_dtype, k, type(draft).__name__)
        st = eng.stats()
        if k > 1 and isinstance(draft, Oracle):
            # the oracle accepts everything: strictly fewer verify
            # steps than tokens, accept rate pinned at 1
            assert st["spec_verify_steps"] > 0
            assert st["spec_accept_rate"] == pytest.approx(1.0)
        if isinstance(draft, Adversarial) and k > 1:
            assert st["spec_accepted"] == 0


@pytest.mark.parametrize("placement", ["float", "identity"])
def test_token_parity_page_placements(placement, monkeypatch):
    """Rejection truncation under BOTH page placements: float restamps
    idx/block tables from host lengths every step (truncation is
    free); identity must walk the live device idx back after a
    rejected window (``PagedKVCache.commit``)."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    monkeypatch.setenv("REPRO_PAGED_PLACEMENT", placement)
    cfg = _cfg("fp8")
    params = _params(cfg)
    truth = _truth(cfg, params, MIXED_LENS)
    for draft in (Oracle(truth), HalfOracle(truth, good=1)):
        got, eng = _serve(cfg, params, MIXED_LENS, spec=True,
                          draft=draft, spec_k=4)
        assert got == truth, (placement, type(draft).__name__)
        assert eng.stats()["spec_verify_steps"] > 0


def test_eos_inside_draft_window(monkeypatch):
    """EOS arriving as an ACCEPTED DRAFT mid-window stops the request
    at exactly the plain-decode length — later drafts in the same
    window must not commit."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    cfg = _cfg("fp8")
    params = _params(cfg)
    free = _truth(cfg, params, [5], max_new=10)
    eos = free[0][4]                       # stop at output position 5
    truth = _truth(cfg, params, [5], max_new=10, eos=eos)
    assert len(truth[0]) == 5
    got, eng = _serve(cfg, params, [5], spec=True, max_new=10, eos=eos,
                      draft=Oracle(free), spec_k=4)
    assert got == truth
    assert eng.stats()["spec_verify_steps"] > 0


def test_mixed_depth_batches_and_budgets(monkeypatch):
    """Rows at different prompt depths AND different max_new budgets
    share one verify launch; k clamps to the tightest remaining
    budget, so no row ever overruns max_new."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    cfg = _cfg("fp8")
    params = _params(cfg)
    lens = [(5, 3), (9, 10), (17, 7)]      # (prompt_len, max_new)
    truth = _truth(cfg, params, lens)
    got, _ = _serve(cfg, params, lens, spec=True, draft=Oracle(truth),
                    spec_k=4)
    assert got == truth
    for rid, (_, mn) in enumerate(lens):
        assert len(got[rid]) == mn


@pytest.mark.slow
@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
@pytest.mark.parametrize("placement", ["float", "identity"])
def test_token_parity_interpret_backend(kv_dtype, placement,
                                        monkeypatch):
    """The full matrix leg on the Pallas-interpret backend: the
    verify step runs through the REAL batched-query kernel (in-step
    causal mask, draft-major rows) and still reproduces plain decode
    token-for-token."""
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.setenv("REPRO_PAGED_PLACEMENT", placement)
    cfg = _cfg(kv_dtype)
    params = _params(cfg)
    lens = [5, 9]
    truth = _truth(cfg, params, lens, max_new=6)
    for draft in (Oracle(truth), HalfOracle(truth, good=1)):
        got, eng = _serve(cfg, params, lens, spec=True, max_new=6,
                          draft=draft, spec_k=3)
        assert got == truth, (kv_dtype, placement,
                              type(draft).__name__)
        assert eng.stats()["spec_verify_steps"] > 0


# ---------------------------------------------------------------------------
# Step-level: one (B, k) verify == k sequential decode steps, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
def test_verify_step_bitwise_vs_sequential_decode(kv_dtype):
    """``make_verify_step``'s k logit rows are BITWISE the k
    sequential ``make_decode_step`` calls they replace: per-position
    K/V quantization (amax over Dh only), batch-independent DELAYED
    activation scales (the serving default — a just-in-time per-tensor
    amax would see k tokens instead of 1 and shift every scale) and
    the per-draft validity mask together make the verify graph a pure
    re-bracketing of the sequential computation."""
    from repro.core.actscale import calibrate_act_scales

    # full serving stack: fp8 weight quant + prequantized params
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        kv_cache_dtype=kv_dtype)
    params = _params(cfg)
    pq = prequantize_params(cfg, params)
    act = calibrate_act_scales(cfg, pq.qweights, pq.scales)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales,
                                    act_scales=act))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab)
    _, caches0 = pre(pq.qweights, {"tokens": toks})

    dec = jax.jit(make_decode_step(cfg, scales=pq.scales,
                                   act_scales=act))
    feed0 = toks[:, :1]
    seq_logits, caches = [], caches0
    cur = feed0
    for _ in range(4):
        lo, caches = dec(pq.qweights, caches, cur)
        seq_logits.append(np.asarray(lo[:, 0]))
        cur = jnp.argmax(lo[:, -1], axis=-1)[:, None].astype(jnp.int32)

    # verify feed = [t0, d1, d2, d3] with d_j the greedy continuation
    _, caches = pre(pq.qweights, {"tokens": toks})   # fresh prefill
    ver = jax.jit(make_verify_step(cfg, scales=pq.scales,
                                   act_scales=act))
    drafts = np.stack([np.argmax(s, axis=-1) for s in seq_logits[:3]],
                      axis=1)
    feed = np.concatenate([np.asarray(feed0), drafts], axis=1)
    vlo, _ = ver(pq.qweights, caches, jnp.asarray(feed, jnp.int32))
    for j in range(4):
        assert np.array_equal(np.asarray(vlo[:, j]), seq_logits[j]), \
            (kv_dtype, j)


# ---------------------------------------------------------------------------
# Kernel-level: the batched-query in-step causal mask
# ---------------------------------------------------------------------------


def _kernel_fixture(kv_dtype, b=2, kvh=2, g=4, c=48, dh=32, seed=0):
    rng = np.random.default_rng(seed)
    s_len = 3
    q = jnp.asarray(rng.standard_normal((b, kvh, s_len, g, dh)),
                    jnp.bfloat16)
    kf = jnp.asarray(rng.standard_normal((b, kvh, c, dh)))
    vf = jnp.asarray(rng.standard_normal((b, kvh, c, dh)))
    if kv_dtype == "fp8":
        k, ks = A._quant_kv(kf)
        v, vs = A._quant_kv(vf)
    else:
        k, v = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
        ks = vs = None
    nv = jnp.asarray([17, 41], jnp.int32)   # POST-write depths, >= s_len
    return q, k, v, ks, vs, nv


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_batched_query_rows_bitwise_vs_single_query(kv_dtype, backend):
    """Draft row j of ONE 5-D launch == the 4-D single-query launch
    at that draft's own validity window (n_valid - (S-1-j)), bitwise:
    the in-step causal mask reproduces each sequential step's window
    exactly, so sharing one cache read loses nothing."""
    q, k, v, ks, vs, nv = _kernel_fixture(kv_dtype)
    s_len = q.shape[2]
    out = dispatch.decode_attention(q, k, v, ks, vs, nv,
                                    backend=backend)
    assert out.shape == q.shape
    for j in range(s_len):
        solo = dispatch.decode_attention(q[:, :, j], k, v, ks, vs,
                                         nv - (s_len - 1 - j),
                                         backend=backend)
        assert jnp.array_equal(out[:, :, j], solo), (kv_dtype,
                                                     backend, j)


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
def test_batched_query_ref_vs_interpret_bitwise(kv_dtype):
    """5-D ref (einsum oracle) vs interpret (Pallas kernel) — single
    C block replays the exact softmax in the reference operation
    order, so across backends the verify step is bitwise too."""
    q, k, v, ks, vs, nv = _kernel_fixture(kv_dtype, seed=3)
    outs = {b: dispatch.decode_attention(q, k, v, ks, vs, nv,
                                         backend=b)
            for b in ("ref", "interpret")}
    assert jnp.array_equal(outs["ref"], outs["interpret"])


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
def test_batched_query_paged_shares_one_page_read(kv_dtype):
    """The paged variant: k draft queries share ONE gather of the fp8
    KV pages — row parity against the paged single-query launch at
    shifted windows, both backends."""
    q, k, v, ks, vs, nv = _kernel_fixture(kv_dtype, c=64, seed=5)
    t, n_p = 16, 4
    pool = lambda a: (None if a is None else jnp.concatenate(
        [a[i].reshape(a.shape[1], n_p, t, *a.shape[3:]).swapaxes(0, 1)
         for i in range(a.shape[0])], axis=0))
    pk, pv, pks, pvs = pool(k), pool(v), pool(ks), pool(vs)
    bt = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    s_len = q.shape[2]
    for backend in ("ref", "interpret"):
        out = dispatch.decode_attention_paged(q, pk, pv, pks, pvs, nv,
                                              bt, backend=backend)
        for j in range(s_len):
            solo = dispatch.decode_attention_paged(
                q[:, :, j], pk, pv, pks, pvs,
                nv - (s_len - 1 - j), bt, backend=backend)
            assert jnp.array_equal(out[:, :, j], solo), (kv_dtype,
                                                         backend, j)


# ---------------------------------------------------------------------------
# jaxpr: the verify step keeps the reduction-free serving contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
def test_verify_jaxpr_zero_dequant_and_quant_reductions(kv_dtype,
                                                        monkeypatch):
    """The batched-query verify graph inherits every serving-graph
    contract the single-token decode earned: ZERO cache-sized fp8
    dequant upcasts, ZERO cache-sized dot_generals (the k draft
    queries ride the fused kernel's one page read), and zero
    quantization amax reductions — outright on a bf16 cache; on fp8
    exactly the TWO per-position K/V storage-write amaxes remain
    (they quantize the k incoming tokens, not the cache)."""
    from repro.core.actscale import calibrate_act_scales
    from repro.core.introspect import (
        count_dot_general_over,
        count_fp8_dequant_upcasts,
        count_quant_reductions,
        kv_cache_slice_sizes,
    )

    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.delenv("REPRO_DECODE_ATTN", raising=False)
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        kv_cache_dtype=kv_dtype)
    params = _params(cfg)
    pq = prequantize_params(cfg, params)
    act = calibrate_act_scales(cfg, pq.qweights, pq.scales)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales,
                                    act_scales=act))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab)
    _, caches = pre(pq.qweights, {"tokens": toks})
    feed = toks[:, :4]                     # k = 4 verify window
    jx = jax.make_jaxpr(make_verify_step(cfg, scales=pq.scales,
                                         act_scales=act))(
        pq.qweights, caches, feed)
    sizes = kv_cache_slice_sizes(cfg, 2, 16)
    assert count_fp8_dequant_upcasts(jx, sizes) == 0
    assert count_dot_general_over(jx, sizes) == 0
    storage_amaxes = 2 if kv_dtype == "fp8" else 0
    assert count_quant_reductions(jx) == storage_amaxes


# ---------------------------------------------------------------------------
# Draft sources + gating
# ---------------------------------------------------------------------------


def test_ngram_draft_prompt_lookup():
    """Suffix lookup basics: longest n-gram wins, the most recent
    earlier occurrence wins, empty when nothing matches."""
    d = NgramDraft(max_ngram=3)
    req = Request(rid=0, prompt=np.asarray([7, 8, 9, 1, 2, 3, 4, 5],
                                           np.int32), max_new=8)
    req.out = [1, 2, 3]
    # suffix (1,2,3) recurs at position 3 -> propose its continuation
    assert d.propose(req, 4) == [4, 5, 1, 2]
    req.out = [99]
    assert d.propose(req, 4) == []         # 99 never seen before
    # recency: the LAST earlier occurrence's continuation wins
    req2 = Request(rid=1, prompt=np.asarray([1, 2, 5, 1, 2, 6, 1, 2],
                                            np.int32), max_new=8)
    assert d.propose(req2, 1) == [6]


def test_model_draft_hook():
    calls = []

    def propose_fn(ctx, k):
        calls.append((tuple(ctx), k))
        return [41, 42, 43][:k]

    d = ModelDraft(propose_fn)
    assert isinstance(d, DraftSource)
    req = Request(rid=0, prompt=np.asarray([1, 2], np.int32),
                  max_new=4)
    req.out = [3]
    assert d.propose(req, 2) == [41, 42]
    assert calls == [((1, 2, 3), 2)]


def test_spec_gate_requires_chunked_v2(monkeypatch):
    """The verify step rides the v2 mixed-step support surface: with
    chunked prefill off the spec flag is inert (plain decode), and
    the env flag mirrors the constructor arg."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    cfg = _cfg("fp8")
    params = _params(cfg)
    assert spec_verify_supported(cfg, MAX_LEN)
    monkeypatch.setenv("REPRO_CHUNKED_PREFILL", "0")
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN,
                 spec_decode=True)
    assert not eng.spec
    monkeypatch.delenv("REPRO_CHUNKED_PREFILL")
    monkeypatch.setenv("REPRO_SPEC_DECODE", "1")
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN)
    assert eng.spec
    monkeypatch.setenv("REPRO_SPEC_DECODE", "0")
    eng = Engine(cfg, params, num_slots=2, max_len=MAX_LEN)
    assert not eng.spec
    # fp8 activation quant WITHOUT delayed scales: a (B, k) window
    # would measure different per-tensor act amaxes than the (B, 1)
    # steps it replaces — inexact, so the gate stays off
    monkeypatch.setenv("REPRO_SERVE_DELAYED_ACT", "0")
    fcfg = get_config("phi3-mini-3.8b", smoke=True)
    eng = Engine(fcfg, _params(fcfg), num_slots=2, max_len=MAX_LEN,
                 spec_decode=True)
    assert not eng.spec
    monkeypatch.delenv("REPRO_SERVE_DELAYED_ACT")
    eng = Engine(fcfg, _params(fcfg), num_slots=2, max_len=MAX_LEN,
                 spec_decode=True)
    assert eng.spec


def test_accept_rate_ema_steers_draft_len():
    """Scheduler policy units: the EMA starts optimistic, decays
    toward the observed accept rate, and ``draft_len`` scales the
    configured maximum (floored at 2 so the EMA can recover)."""
    from repro.serving import Scheduler

    s = Scheduler()
    assert s.draft_len(4) == 4             # optimistic start
    for _ in range(20):
        s.on_verify(proposed=6, accepted=0)
    assert s.accept_rate < 0.05
    assert s.draft_len(8) == 2             # floored, never 1
    assert s.draft_len(2) == 2             # k_max <= 2 passes through
    assert s.draft_len(1) == 1
    for _ in range(30):
        s.on_verify(proposed=6, accepted=6)
    assert s.accept_rate > 0.95
    assert s.draft_len(8) == 8
    st = s.summary()
    assert st["spec_verify_steps"] == 50
    assert st["spec_drafted"] == 300


# ---------------------------------------------------------------------------
# Satellite: PR 2 NOTE regression — small-T single-device MoE train
# short-circuits to the dense decode combine unless moe_decode_dense
# is explicitly disabled
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dense_flag,expect_dense", [(True, True),
                                                     (False, False)])
def test_moe_small_t_train_routes_dense_combine(dense_flag,
                                                expect_dense,
                                                monkeypatch):
    """Pin the PR 2 routing decision: on a single device with small T
    the TRAIN path short-circuits to the dense decode combine (one
    gather-free einsum) unless ``moe_decode_dense=False`` — future
    engine/scheduler changes must not silently flip it."""
    from repro.models import moe as M
    from repro.models.layers import quant_mask_tree, wrap_qt_nojit

    monkeypatch.setenv("REPRO_KERNELS", "ref")
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
        quant=BF16_CONFIG, moe_decode_dense=dense_flag)
    defs = M.moe_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    seen = []
    real_dense, real_dc = M._dense_moe, M._dispatch_combine_local

    def spy_dense(*a, **kw):
        seen.append("dense")
        return real_dense(*a, **kw)

    def spy_dc(*a, **kw):
        seen.append("dispatch")
        return real_dc(*a, **kw)

    monkeypatch.setattr(M, "_dense_moe", spy_dense)
    monkeypatch.setattr(M, "_dispatch_combine_local", spy_dc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.bfloat16)                 # T = 16 << 4096
    M.moe_block(cfg, qp, x, cfg.quant, mode="train")
    assert seen == (["dense"] if expect_dense
                    else ["dispatch"]), (dense_flag, seen)
