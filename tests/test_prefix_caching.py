"""Copy-on-write prefix caching over the floating page pool
(docs/paged-attention.md):

- allocator units: refcount/free-list bookkeeping, double-free and
  reservation-leak assertions, ensure_writable's fresh/ok/cow state
  machine, LRU eviction of parked hashed pages and prefix revival;
- ``page_keys`` chaining: a key identifies the whole prefix, not just
  one page's tokens;
- engine-level physical sharing: two requests with a page-aligned
  common prefix genuinely share pages (asserted on allocator state),
  the second chunk-prefills ONLY its unshared suffix, and a write
  into a shared page copies-before-write;
- the donor is bitwise unperturbed by sharing (vs solo serving), the
  sharer's outputs are deterministic across fresh engines, and the
  prefix map survives retirement (evictable pages revive on hit);
- floating-vs-identity placement token parity, fp8 AND bf16 cache,
  ref AND interpret backends (``REPRO_PAGED_PLACEMENT`` A/B).
"""

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.formats import BF16_CONFIG
from repro.models.layers import init_tree
from repro.models.transformer import model_defs
from repro.serving import (
    Engine,
    PageAllocator,
    Request,
    page_keys,
)

T = 16                                  # serving PAGE_SIZE


def _cfg(kv_dtype="bf16"):
    return get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype=kv_dtype)


def _params(cfg):
    return init_tree(model_defs(cfg), jax.random.PRNGKey(0))


def _prompt(rng, n):
    return rng.integers(0, 64, size=n, dtype=np.int32)


# ---------------------------------------------------------------------------
# page_keys: chained page-aligned prefix hashing
# ---------------------------------------------------------------------------


def test_page_keys_chain_over_the_whole_prefix():
    toks = np.arange(40, dtype=np.int32)
    keys = page_keys(toks, T)
    assert len(keys) == 2               # only FULL pages get keys
    # the frontier partial page never contributes
    assert page_keys(np.concatenate([toks[:32], toks[:5]]), T) == keys
    # a page-0 edit changes EVERY key (chained, not per-page)
    t0 = toks.copy()
    t0[3] += 1
    k0 = page_keys(t0, T)
    assert k0[0] != keys[0] and k0[1] != keys[1]
    # a page-1 edit leaves key 0 alone
    t1 = toks.copy()
    t1[20] += 1
    k1 = page_keys(t1, T)
    assert k1[0] == keys[0] and k1[1] != keys[1]
    assert page_keys(toks[:15], T) == []


# ---------------------------------------------------------------------------
# Allocator units: refcounts, guards, CoW state machine, eviction
# ---------------------------------------------------------------------------


def test_allocator_refcounted_sharing_and_release():
    al = PageAllocator(num_pages=8, page_size=4, slot_tokens=32)
    donor = al.admit(owner=1, prompt_tokens=8, total_tokens=8)
    for page, key in zip(donor.pages, ["a", "b"]):
        assert al.register_hash(page, key)
    assert al.lookup(["a", "b"]) == donor.pages
    assert al.lookup(["a", "zzz"]) == donor.pages[:1]   # longest run
    # a second owner maps the shared pages: refcount 2, no new alloc
    bt = al.admit(owner=2, prompt_tokens=0, total_tokens=12,
                  shared=donor.pages)
    assert bt.pages == donor.pages and bt.shared0 == 2
    assert all(al.refcount(p) == 2 for p in donor.pages)
    assert al.free_pages == 6           # nothing allocated for owner 2
    al.release(1)                       # donor retires first
    assert all(al.refcount(p) == 1 for p in donor.pages)
    al.release(2)                       # hashed pages park, not free
    assert al.cached_pages == 2 and al.free_pages == 8
    assert al.lookup(["a", "b"]) == donor.pages   # still hittable


def test_allocator_double_free_and_reservation_leak_guards():
    al = PageAllocator(num_pages=4, page_size=4)
    bt = al.admit(owner=1, prompt_tokens=4, total_tokens=4)
    with pytest.raises(AssertionError, match="overrun"):
        al._alloc_private(bt)           # reserved 1, private already 1
    al2 = PageAllocator(num_pages=4, page_size=4)
    page = al2.admit(owner=1, prompt_tokens=4, total_tokens=4).pages[0]
    al2._unref(page)
    with pytest.raises(AssertionError, match="double-free"):
        al2._unref(page)


def test_allocator_ensure_writable_state_machine():
    al = PageAllocator(num_pages=8, page_size=4, slot_tokens=32)
    bt = al.admit(owner=1, prompt_tokens=4, total_tokens=16)
    assert al.ensure_writable(1, 0)[0] == "ok"     # private, unhashed
    al.register_hash(bt.pages[0], "x")
    kind, old, new = al.ensure_writable(1, 0)      # hashed even at rc1
    assert kind == "cow" and old != new and bt.pages[0] == new
    assert al.cached_pages == 1         # the pristine page parked
    kind, page, _ = al.ensure_writable(1, 1)       # one past frontier
    assert kind == "fresh" and bt.pages[1] == page
    # rc>1 CoW: a sharer writing into a still-referenced page
    shared = al.lookup(["x"])
    bt2 = al.admit(owner=2, prompt_tokens=0, total_tokens=8,
                   shared=shared)
    al.admit(owner=3, prompt_tokens=0, total_tokens=8, shared=shared)
    kind, old, new = al.ensure_writable(2, 0)
    assert kind == "cow" and bt2.pages[0] == new
    assert al.refcount(old) == 1        # owner 3 still holds it


def test_allocator_lru_eviction_drops_the_prefix():
    al = PageAllocator(num_pages=4, page_size=4)
    bt = al.admit(owner=1, prompt_tokens=16, total_tokens=16)
    keys = ["k0", "k1", "k2", "k3"]
    for page, key in zip(bt.pages, keys):
        al.register_hash(page, key)
    al.release(1)
    assert al.cached_pages == 4 and al.free_pages == 4
    # a fresh admission must reclaim parked pages, oldest first; the
    # evicted page's hash dies with it, and because keys are CHAINED
    # the whole prefix becomes unhittable (honest, not corrupt)
    al.admit(owner=2, prompt_tokens=8, total_tokens=8)
    assert al.cached_pages == 2
    assert al.lookup(keys) == []


def test_allocator_evictable_pages_revive_on_hit():
    al = PageAllocator(num_pages=4, page_size=4)
    donor = al.admit(owner=1, prompt_tokens=8, total_tokens=8)
    for page, key in zip(donor.pages, ["a", "b"]):
        al.register_hash(page, key)
    al.release(1)
    hit = al.lookup(["a", "b"])
    assert hit == donor.pages
    # reviving the parked pages consumes free-pool headroom: a request
    # needing them PLUS more than the remainder must not admit
    assert al.can_admit(8, shared=hit)
    assert not al.can_admit(16, shared=hit, cow_slack=1)
    bt = al.admit(owner=2, prompt_tokens=0, total_tokens=12, shared=hit)
    assert al.cached_pages == 0 and bt.shared0 == 2
    assert all(al.refcount(p) == 1 for p in hit)


# ---------------------------------------------------------------------------
# Engine-level sharing: the acceptance contract
# ---------------------------------------------------------------------------


def test_engine_prefix_hit_shares_pages_and_skips_prefill():
    """Two requests with a 2-page common prefix: the second maps the
    donor's PHYSICAL pages (same ids, refcount 2 — asserted on
    allocator state), chunk-prefills only its 5-token suffix, and
    both complete."""
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prefix = _prompt(rng, 2 * T)
    donor = Request(rid=0, prompt=prefix, max_new=4)
    sharer = Request(rid=1, prompt=np.concatenate(
        [prefix, _prompt(rng, 5)]), max_new=4)
    eng = Engine(cfg, params, num_slots=2, max_len=48)
    assert eng.float_pages and eng.prefix_cache and eng.chunked
    eng.submit([donor, sharer])
    eng.step()                          # both admitted in one step
    al = eng.kv.allocator
    bt0, bt1 = al.table(0), al.table(1)
    assert bt1.pages[:2] == bt0.pages[:2] and bt1.shared0 == 2
    assert all(al.refcount(p) == 2 for p in bt0.pages[:2])
    assert eng.prefill_calls == 0       # nobody whole-prompt prefilled
    assert eng.chunk_prefill_steps == 2  # 32-tok donor + 5-tok suffix
    assert eng.prefix_hits == 1 and eng.pages_shared == 2
    assert sharer.prefix_pages == 2
    assert sharer.prefill_skipped == 2 * T
    eng.run(log=None)                   # drain
    assert donor.done and sharer.done
    assert len(donor.out) == 4 and len(sharer.out) == 4
    # partial hit: the sharer's suffix chunk lands in its own fresh
    # page past the shared prefix — no copy-on-write needed
    assert eng.kv.cow_copies == 0
    assert al.free_pages == al.num_pages and al.cached_pages >= 2


def test_engine_full_hit_triggers_exactly_one_cow():
    """An IDENTICAL prompt is a full page-aligned hit: its one-token
    suffix chunk writes into the shared frontier page, which must
    copy-before-write (the donor's registered page stays pristine —
    asserted by a THIRD identical request still hitting both pages)."""
    cfg = _cfg()
    params = _params(cfg)
    prompt = _prompt(np.random.default_rng(1), 2 * T)
    donor = Request(rid=0, prompt=prompt, max_new=4)
    sharer = Request(rid=1, prompt=prompt.copy(), max_new=4)
    eng = Engine(cfg, params, num_slots=2, max_len=48)
    eng.submit([donor, sharer])
    eng._retire()
    eng._chunk_phase()                  # stage + attach, no decode yet
    al = eng.kv.allocator
    assert al.table(1).shared0 == 2     # mapped both donor pages
    # the suffix chunk already copied the frontier page on write: the
    # sharer now owns a private copy, the donor's stays registered
    assert eng.kv.cow_copies == 1
    assert al.table(1).pages[0] == al.table(0).pages[0]
    assert al.table(1).pages[1] != al.table(0).pages[1]
    eng.run(log=None)
    assert eng.kv.cow_copies == 1       # exactly one, ever
    assert eng.prefill_calls == 0
    assert sharer.prefill_skipped == 2 * T - 1   # last token chunked
    assert donor.done and sharer.done and len(sharer.out) == 4
    third = Request(rid=2, prompt=prompt.copy(), max_new=4)
    eng.run([third], log=None)
    assert eng.prefix_hits == 2 and third.prefix_pages == 2
    assert third.out == sharer.out


def test_donor_is_unperturbed_by_sharing():
    """Copy-on-write correctness, observed end to end: the donor's
    greedy continuation is token-for-token identical whether or not a
    sharer mapped (and then diverged from) its prefix pages."""
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(2)
    prefix = _prompt(rng, 2 * T)
    suffix = _prompt(rng, 5)
    solo = Request(rid=0, prompt=prefix, max_new=6)
    Engine(cfg, params, num_slots=1, max_len=48).run([solo], log=None)
    donor = Request(rid=0, prompt=prefix, max_new=6)
    sharer = Request(rid=1, prompt=np.concatenate([prefix, suffix]),
                     max_new=6)
    eng = Engine(cfg, params, num_slots=2, max_len=48)
    eng.run([donor, sharer], log=None)
    assert eng.prefix_hits == 1
    assert donor.out == solo.out, (donor.out, solo.out)


def test_sharer_outputs_deterministic_across_engines():
    """The hit-suffix chunk path is deterministic: a fresh engine
    serving the same shared-prefix trace reproduces every output."""
    cfg = _cfg()
    params = _params(cfg)

    def serve():
        rng = np.random.default_rng(3)
        prefix = _prompt(rng, 2 * T)
        reqs = [Request(rid=0, prompt=prefix, max_new=4),
                Request(rid=1,
                        prompt=np.concatenate([prefix, _prompt(rng, 3)]),
                        max_new=4)]
        eng = Engine(cfg, params, num_slots=2, max_len=48)
        eng.run(reqs, log=None)
        assert eng.prefix_hits == 1
        return [r.out for r in reqs]

    assert serve() == serve()


def test_prefix_map_survives_retirement():
    """A retired donor's hashed pages park evictable and revive on the
    next hit: the second serve of the same prompt runs ZERO prefill."""
    cfg = _cfg()
    params = _params(cfg)
    prompt = _prompt(np.random.default_rng(4), 2 * T)
    eng = Engine(cfg, params, num_slots=1, max_len=48, num_pages=6)
    first = Request(rid=0, prompt=prompt, max_new=3)
    eng.run([first], log=None)
    al = eng.kv.allocator
    assert al.free_pages == al.num_pages and al.cached_pages == 2
    second = Request(rid=1, prompt=prompt.copy(), max_new=3)
    eng.run([second], log=None)
    # revival, not re-prefill: only the final prompt token chunks
    assert second.prefill_skipped == 2 * T - 1
    assert eng.prefix_hits == 1 and second.prefix_pages == 2
    assert second.done and len(second.out) == 3


def test_full_hit_on_minimal_pool_falls_back_to_cold():
    """On a pool exactly the size of one slot, a full-hit admission
    (page revival + CoW slack) needs more headroom than a cold one:
    the engine must serve the request cold, not livelock the FIFO."""
    cfg = _cfg()
    params = _params(cfg)
    prompt = _prompt(np.random.default_rng(7), 2 * T)
    eng = Engine(cfg, params, num_slots=1, max_len=48)   # 3-page pool
    first = Request(rid=0, prompt=prompt, max_new=3)
    eng.run([first], log=None)
    second = Request(rid=1, prompt=prompt.copy(), max_new=3)
    eng.run([second], log=None)
    assert eng.prefix_hits == 0 and eng.pages_shared == 0
    assert second.prefill_skipped == 0        # served cold, in full
    assert second.done and second.out == first.out


def test_prefix_cache_off_never_shares():
    cfg = _cfg()
    params = _params(cfg)
    prompt = _prompt(np.random.default_rng(5), 2 * T)
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new=3)
            for i in range(2)]
    eng = Engine(cfg, params, num_slots=2, max_len=48,
                 prefix_cache=False)
    eng.run(reqs, log=None)
    assert eng.prefix_hits == 0 and eng.pages_shared == 0
    assert reqs[0].out == reqs[1].out   # identical prompts, greedy


# ---------------------------------------------------------------------------
# Floating vs identity placement: token parity A/B
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_float_vs_identity_placement_parity(monkeypatch, kv_dtype,
                                            backend):
    """The floating pool is a pure PLACEMENT change: serving the same
    mixed-length trace under ``REPRO_PAGED_PLACEMENT=identity`` (the
    PR5 contiguous rows) and ``float`` (gathered pages) produces the
    same tokens, fp8 and bf16 cache, ref and kernel backends."""
    monkeypatch.setenv("REPRO_KERNELS", backend)
    cfg = _cfg(kv_dtype)
    params = _params(cfg)
    lens = [6, 17, 11]

    def serve(placement):
        monkeypatch.setenv("REPRO_PAGED_PLACEMENT", placement)
        rng = np.random.default_rng(6)
        reqs = [Request(rid=i, prompt=_prompt(rng, n), max_new=4)
                for i, n in enumerate(lens)]
        eng = Engine(cfg, params, num_slots=2, max_len=32)
        assert eng.float_pages == (placement == "float")
        eng.run(reqs, log=None)
        return [r.out for r in reqs]

    assert serve("float") == serve("identity")
