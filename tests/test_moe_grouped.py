"""Grouped-expert MOSS GEMM (the MoE hot path): ref-vs-interpret kernel
parity on ragged group sizes (including a zero-size expert and a
full-capacity expert), grad-checks of ``qmm_grouped`` against the
per-expert vmapped path, and the MoE train step with the grouped
kernels active end-to-end under ``REPRO_KERNELS=interpret``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as Q
from repro.core.formats import BF16_CONFIG, MOSS_CONFIG
from repro.core.linear import QT, qlinear, qmm_grouped
from repro.core.quant import quant_per_tensor
from repro.kernels import dispatch

E, C, D, F = 4, 32, 64, 48
# ragged: one full-capacity expert, one empty expert, two partial
SIZES = jnp.array([C, 0, 5, 19], jnp.int32)


def _buffer(key=0, sizes=SIZES, d=D):
    """A dispatch-shaped (E·C, d) buffer: rows past each expert's valid
    count are zero, and every token carries a ±3.0 entry so all level-1
    amaxes coincide (see test_grouped_matches_vmapped_bitexact)."""
    x = jax.random.normal(jax.random.PRNGKey(key), (E * C, d), jnp.float32)
    x = jnp.clip(x, -2.5, 2.5).at[:, 0].set(3.0)
    pos = jnp.arange(E * C) % C
    valid = pos < sizes[jnp.arange(E * C) // C]
    return jnp.where(valid[:, None], x, 0.0)


def _weights(key=1, d=D, f=F):
    return jax.random.normal(jax.random.PRNGKey(key), (E, d, f),
                             jnp.float32) * 0.05


def _fwd_bwd(x, w, backend, monkeypatch, sizes=SIZES):
    monkeypatch.setenv("REPRO_KERNELS", backend)

    def loss(x, w):
        ws = jnp.max(jnp.abs(w), axis=(1, 2)) / 448.0
        y = qmm_grouped(MOSS_CONFIG, C, x, w, ws, sizes)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    val, grads = jax.value_and_grad(loss, argnums=(0, 1))(x, w)
    return float(val), grads


def test_grouped_interpret_matches_ref(monkeypatch):
    """fwd, dx and dW of the grouped custom-VJP: the Pallas kernels
    (interpreted) against the jnp reference, on ragged sizes with an
    empty and a full-capacity expert."""
    x, w = _buffer(), _weights()
    v_ref, (gx_ref, gw_ref) = _fwd_bwd(x, w, "ref", monkeypatch)
    v_int, (gx_int, gw_int) = _fwd_bwd(x, w, "interpret", monkeypatch)
    assert abs(v_int - v_ref) <= 1e-5 * abs(v_ref)
    for g_i, g_r in ((gx_int, gx_ref), (gw_int, gw_ref)):
        rel = float(jnp.linalg.norm(g_i - g_r)
                    / (jnp.linalg.norm(g_r) + 1e-9))
        assert rel < 1e-5, rel


def test_grouped_interpret_matches_ref_unaligned_capacity(monkeypatch):
    """C=24 (not a micro-group multiple) exercises the per-expert row
    padding of the grouped dW dispatch; K=80 exercises K padding."""
    cap, d = 24, 80
    sizes = jnp.array([cap, 0, 3, 11], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(3), (E * cap, d), jnp.float32)
    pos = jnp.arange(E * cap) % cap
    x = jnp.where((pos < sizes[jnp.arange(E * cap) // cap])[:, None], x, 0.0)
    w = jax.random.normal(jax.random.PRNGKey(4), (E, d, F), jnp.float32)

    def run(backend):
        monkeypatch.setenv("REPRO_KERNELS", backend)

        def loss(x, w):
            ws = jnp.max(jnp.abs(w), axis=(1, 2)) / 448.0
            y = qmm_grouped(MOSS_CONFIG, cap, x, w, ws, sizes)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        return jax.value_and_grad(loss, argnums=(0, 1))(x, w)

    v_r, g_r = run("ref")
    v_i, g_i = run("interpret")
    assert abs(float(v_i) - float(v_r)) <= 1e-5 * abs(float(v_r))
    for a, b in zip(g_i, g_r):
        rel = float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-9))
        assert rel < 1e-5, rel


def test_grouped_residual_matches_quant_mx(monkeypatch):
    """The grouped kernel's emitted residual must equal a standalone
    two-level quantization of the whole token buffer (one level-1
    scale, per-micro-group exponents)."""
    x, w = _buffer(), _weights()
    wq = jax.vmap(lambda wi: quant_per_tensor(wi, "e4m3"))(w)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    _, xq = dispatch.moe_grouped_matmul(x, SIZES, wq.q, wq.s, capacity=C)
    q_ref = Q.quant_mx(x)
    assert float(xq.s) == float(q_ref.s)
    assert (np.asarray(xq.sexp) == np.asarray(q_ref.sexp)).all()
    np.testing.assert_array_equal(
        np.asarray(xq.q.astype(jnp.float32)),
        np.asarray(q_ref.q.astype(jnp.float32)))


def test_grouped_fwd_bitexact_vs_per_expert_shared_scale(monkeypatch):
    """With the level-1 scale shared, the grouped forward must be
    BITWISE identical to E independent per-expert MX GEMMs — the
    grouped kernel changes the launch structure, not the math."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    x, w = _buffer(), _weights()
    s = jnp.max(jnp.abs(x)) / 448.0
    ws = jnp.max(jnp.abs(w), axis=(1, 2)) / 448.0
    y_grp = qmm_grouped(MOSS_CONFIG, C, x, w, ws, SIZES)
    for e in range(E):
        xq = Q.quant_mx(x[e * C:(e + 1) * C], 32, "e4m3", global_scale=s)
        wq = quant_per_tensor(w[e], "e4m3", scale=ws[e])
        y_e = Q.mx_gemm(xq, wq, out_dtype=jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(y_grp[e * C:(e + 1) * C].astype(jnp.float32)),
            np.asarray(y_e))


def test_grouped_bf16_bitexact_vs_vmapped():
    """bf16 mode: grouped and vmapped are the same dots over the same
    rows — bitwise equal."""
    x, w = _buffer(), _weights()
    y_grp = qmm_grouped(BF16_CONFIG, C, x, w, jnp.zeros((E,), jnp.float32),
                        SIZES)
    y_vm = jax.vmap(lambda xe, we: qlinear(xe, QT(we, None), BF16_CONFIG))(
        x.reshape(E, C, D), w)
    np.testing.assert_array_equal(np.asarray(y_grp.reshape(E, C, F)),
                                  np.asarray(y_vm))


def test_qmm_grouped_grads_match_vmapped_qlinear(monkeypatch):
    """Grad-check against the vmapped path: with every expert's buffer
    carrying the same amax (so per-expert and buffer-global level-1
    scales coincide — see _buffer), moss grouped == vmapped down to
    quantization bit level; compare loss and both grads."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    x, w = _buffer(), _weights()
    ws = jnp.max(jnp.abs(w), axis=(1, 2)) / 448.0

    def loss_grouped(x, w):
        y = qmm_grouped(MOSS_CONFIG, C, x, w, ws, SIZES)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    def loss_vmapped(x, w):
        y = jax.vmap(lambda xe, we, se: qlinear(xe, QT(we, se),
                                                MOSS_CONFIG))(
            x.reshape(E, C, D), w, ws)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    v_g, g_g = jax.value_and_grad(loss_grouped, argnums=(0, 1))(x, w)
    v_v, g_v = jax.value_and_grad(loss_vmapped, argnums=(0, 1))(x, w)
    assert abs(float(v_g) - float(v_v)) <= 1e-6 * abs(float(v_v))
    # backward quantizes the GRADIENT buffer with one level-1 scale
    # (grouped) vs E per-expert scales (vmapped) — the two-level scheme
    # bounds the difference to fp8 noise (effective micro-group scales
    # agree within one power-of-two bucket)
    rel_dx = float(jnp.linalg.norm(g_g[0] - g_v[0])
                   / (jnp.linalg.norm(g_v[0]) + 1e-9))
    rel_dw = float(jnp.linalg.norm(g_g[1] - g_v[1])
                   / (jnp.linalg.norm(g_v[1]) + 1e-9))
    assert rel_dx < 0.05, rel_dx
    assert rel_dw < 0.05, rel_dw


def _moe_block_ab(monkeypatch, quant):
    """Run the same MoE block through the grouped path and the vmapped
    fallback — identical sort-based dispatch, capacity truncation and
    combine; only the expert-GEMM execution differs."""
    from repro.configs.registry import get_config
    from repro.models import moe
    from repro.models.layers import (init_tree, quant_mask_tree,
                                     wrap_qt_nojit)

    monkeypatch.setenv("REPRO_KERNELS", "ref")
    # moe_decode_dense=False so the small-T train path really runs the
    # sort-based dispatch + expert GEMMs (not the dense decode combine)
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
        moe_decode_dense=False)
    cfg = cfg.replace(quant=quant)
    defs = moe.moe_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.bfloat16)

    def block(path):
        monkeypatch.setenv("REPRO_MOE_EXPERTS", path)
        return moe.moe_block(cfg, qp, x, cfg.quant, mode="train")

    (y_g, aux_g), (y_v, aux_v) = block("grouped"), block("vmapped")
    assert float(aux_g) == float(aux_v)
    return y_g.astype(jnp.float32), y_v.astype(jnp.float32)


def test_moe_block_grouped_bitexact_vs_vmapped_bf16(monkeypatch):
    """In bf16 mode the grouped path runs the same dots over the same
    rows as the vmapped experts — the block outputs must be BITWISE
    identical (pins dispatch, truncation and combine equivalence)."""
    y_g, y_v = _moe_block_ab(monkeypatch, BF16_CONFIG)
    np.testing.assert_array_equal(np.asarray(y_g), np.asarray(y_v))


def test_moe_block_grouped_matches_vmapped_moss(monkeypatch):
    """moss mode: grouped quantizes each buffer with ONE level-1 scale
    where vmapped uses E per-expert scales; the two-level scheme keeps
    every effective micro-group scale within the same power-of-two
    bucket of its fine scale, so the block outputs agree to fp8 noise
    (a routing/truncation bug would show up as O(1) error)."""
    y_g, y_v = _moe_block_ab(monkeypatch, MOSS_CONFIG)
    rel = float(jnp.linalg.norm(y_g - y_v) / (jnp.linalg.norm(y_v) + 1e-9))
    # ~4% observed: two independent e4m3 quantizations of the same
    # values through three chained GEMMs; routing errors would be O(1)
    assert rel < 0.08, rel


@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "deepseek-v2-lite-16b"])
def test_moe_train_step_under_interpret(arch, monkeypatch):
    """One real MoE train step with the grouped Pallas kernels active
    (interpreted) end-to-end."""
    from repro.configs.registry import get_config
    from repro.train.steps import (TrainHParams, init_train_state,
                                   make_train_step)

    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.setenv("REPRO_MOE_EXPERTS", "grouped")
    cfg = get_config(arch, smoke=True).replace(moe_decode_dense=False)
    hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=4)
    state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, hp))
    batch = {"tokens": jnp.zeros((2, 64), jnp.int32),
             "labels": jnp.zeros((2, 64), jnp.int32)}
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_moe_expert_path_env(monkeypatch):
    from repro.core.runtime_flags import moe_expert_path

    monkeypatch.delenv("REPRO_MOE_EXPERTS", raising=False)
    assert moe_expert_path() == "grouped"
    monkeypatch.setenv("REPRO_MOE_EXPERTS", "vmapped")
    assert moe_expert_path() == "vmapped"
    monkeypatch.setenv("REPRO_MOE_EXPERTS", "dense")
    with pytest.raises(ValueError):
        moe_expert_path()
