"""Distribution tests — run real multi-device computations on 8 CPU
devices in SUBPROCESSES (the 512-device override belongs only to
dryrun; tests must not pollute this process's device count)."""

import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_dp_training_matches_single_device():
    """Same data, same init: 8-way DP loss == single-device loss."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.train.steps import TrainHParams, init_train_state, make_train_step
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import use_mesh
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_config("olmo-7b", smoke=True)
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=10)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
        batch = data.batch_for_step(0)

        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        _, m_single = jax.jit(make_train_step(cfg, hp))(state, batch)

        mesh = make_host_mesh(model=1)   # 8-way data parallel
        with use_mesh(mesh):
            state2 = init_train_state(cfg, hp, jax.random.PRNGKey(0))
            _, m_dp = jax.jit(make_train_step(cfg, hp, mesh))(state2, batch)
        print("SINGLE", float(m_single["loss"]), "DP", float(m_dp["loss"]))
        assert abs(float(m_single["loss"]) - float(m_dp["loss"])) < 1e-2
    """)
    assert "SINGLE" in out


def test_tp_training_matches_single_device():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_config
        from repro.train.steps import TrainHParams, init_train_state, make_train_step
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import use_mesh
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_config("phi3-mini-3.8b", smoke=True)
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=10)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
        batch = data.batch_for_step(0)
        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        _, m1 = jax.jit(make_train_step(cfg, hp))(state, batch)
        mesh = make_host_mesh(model=4)   # 2 data x 4 model
        with use_mesh(mesh):
            state2 = init_train_state(cfg, hp, jax.random.PRNGKey(0))
            _, m2 = jax.jit(make_train_step(cfg, hp, mesh))(state2, batch)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        print("TPDIFF", d)
        assert d < 1e-2, d
    """)
    assert "TPDIFF" in out


def test_moe_ep_runs_on_mesh():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_config
        from repro.train.steps import TrainHParams, init_train_state, make_train_step
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import use_mesh
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=10)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
        mesh = make_host_mesh(model=4)
        with use_mesh(mesh):
            state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
            step = jax.jit(make_train_step(cfg, hp, mesh))
            losses = []
            for t in range(4):
                state, m = step(state, data.batch_for_step(t))
                losses.append(float(m["loss"]))
        print("EPLOSSES", losses)
        assert all(l == l for l in losses)   # finite
    """)
    assert "EPLOSSES" in out


def test_fp8_grad_compression_converges():
    """fp8 all-reduce with error feedback: loss parity with exact DP."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.configs.registry import get_config
        from repro.core.formats import QuantConfig
        from repro.train.steps import TrainHParams, init_train_state, make_train_step
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import use_mesh
        from repro.data.pipeline import DataConfig, SyntheticLM

        mesh = make_host_mesh(model=1)
        losses = {}
        for comp in (False, True):
            cfg = get_config("olmo-7b", smoke=True).replace(
                quant=QuantConfig(mode="moss", weight_scaling="auto",
                                  grad_comm_fp8=comp))
            hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=30)
            data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                          global_batch=8))
            with use_mesh(mesh):
                state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
                step = jax.jit(make_train_step(cfg, hp, mesh))
                ls = []
                for t in range(30):
                    state, m = step(state, data.batch_for_step(t))
                    ls.append(float(m["loss"]))
            losses[comp] = np.mean(ls[-5:])
        gap = abs(losses[True] - losses[False]) / losses[False]
        print("COMPGAP", gap)
        assert gap < 0.03, gap
    """)
    assert "COMPGAP" in out


def test_elastic_checkpoint_reshard():
    """Save on an 8-device mesh, restore onto 4 devices (elastic)."""
    out = run_with_devices("""
        import tempfile, os
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import manager as ckpt
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import use_mesh, named_sharding

        mesh8 = make_host_mesh(model=1)          # 8x1
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        sh8 = named_sharding(mesh8, ("batch", None), (8, 8))
        xs = jax.device_put(x, sh8)
        d = tempfile.mkdtemp()
        ckpt.save(d, 1, {"x": xs})

        mesh4 = jax.sharding.Mesh(
            np.asarray(jax.devices()[:4]).reshape(4, 1), ("data", "model"))
        sh4 = named_sharding(mesh4, ("batch", None), (8, 8))
        tree, step = ckpt.restore(d, {"x": x}, shardings={"x": sh4})
        assert (np.asarray(tree["x"]) == np.asarray(x)).all()
        print("RESHARD_OK", tree["x"].sharding.num_devices)
    """)
    assert "RESHARD_OK 4" in out


def test_dryrun_single_cell_small_mesh():
    """End-to-end dryrun machinery on an 8-device 4x2 mesh (fast proxy
    for the 256/512-chip meshes exercised by launch/dryrun.py)."""
    out = run_with_devices("""
        import numpy as np, jax
        jax.devices()   # pin the 8-device platform BEFORE importing
        # dryrun (which sets the 512-device XLA flag for its own use)
        from jax.sharding import Mesh
        from repro.compat import jaxapi
        from repro.core import runtime_flags
        runtime_flags.force_bf16_operands(True)
        from repro.launch.dryrun import build_cell, parse_collectives, SHAPES
        from repro.distributed.sharding import use_mesh

        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
        fn, args, shardings, donate = build_cell("phi3-mini-3.8b", "train_4k", mesh)
        with use_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=shardings, donate_argnums=donate
                              ).lower(*args)
            compiled = lowered.compile()
            coll = parse_collectives(compiled.as_text())
        print("CELL_OK", jaxapi.cost_analysis(compiled).get("flops", 0) > 0,
              coll["total_bytes"] > 0)
    """)
    assert "CELL_OK True True" in out
