"""Scheduler v2: chunked prefill, preemption with swap-to-host, and
usage-based admission (docs/continuous-batching.md).

- chunked-vs-whole-prompt parity: the v2 engine (prompts chunk-
  prefilled at an offset through the mixed decode-mode step) produces
  token-for-token the same outputs as the v1 whole-prompt-prefill
  engine on a bf16 cache, ref AND interpret backends — modulo genuine
  argmax ties: the f32 score/softmax reductions run at a different
  width (chunk vs padded prompt), so logits move by a few bf16 ULP,
  and on a random-weights smoke model (near-uniform logits) that can
  flip a tie.  Every divergence must be between tokens the reference
  whole-prompt forward scores within ULP noise of its max — a real
  chunking bug (garbage attended, wrong mask) shifts logits far more
  and fails the tie check.  The fp8 cache leg asserts batch-
  composition independence (mixed vs solo, exact) — chunked fp8
  cannot be token-identical to whole-prompt because chunk attention
  reads the quantized history back while whole-prompt prefill attends
  the fresh bf16 values;
- a prefix-hit's unshared suffix chunk-prefills to exactly the same
  tokens as a cold serve of the same prompt (the replay path this
  replaced is gone);
- preempt/swap-out/swap-in round-trips the victim's pages BITWISE
  (payloads and scales) and the resumed request finishes with exactly
  the tokens solo serving produces;
- usage-based admission packs more concurrency than v1's worst-case
  reservation on the same minimal pool, preempts on growth, and
  still matches solo outputs;
- the scheduler's SLO policy units: chunk_budget reacts to TTFT/TPOT
  pressure, pick_victim chooses the most TPOT headroom — model-free,
  injectable clock.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.formats import BF16_CONFIG
from repro.models.layers import init_tree
from repro.models.transformer import model_defs
from repro.serving import Engine, Request, Scheduler, SLOTargets


def _cfg(kv_dtype="bf16"):
    return get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype=kv_dtype)


def _params(cfg):
    return init_tree(model_defs(cfg), jax.random.PRNGKey(0))


def _requests(cfg, lens, max_new=4, seed=0, rid0=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab, size=n,
                                        dtype=np.int32),
                    max_new=max_new)
            for i, n in enumerate(lens)]


def _solo(cfg, params, reqs, max_len, **kw):
    outs = []
    for r in reqs:
        s = Request(rid=1000 + r.rid, prompt=r.prompt.copy(),
                    max_new=r.max_new)
        Engine(cfg, params, num_slots=1, max_len=max_len, **kw).run(
            [s], log=None)
        outs.append(s.out)
    return outs


# prompt lengths straddle chunk boundaries on purpose: shorter than a
# chunk, one exact chunk, chunk+1, and several chunks with a tail
CHUNK = 8
MIXED_LENS = [5, 8, 9, 29]


# ---------------------------------------------------------------------------
# Chunked vs whole-prompt parity — the acceptance contract
# ---------------------------------------------------------------------------

# bf16 ULP at the smoke model's logit magnitudes (~2-4) is 0.016-0.03;
# a flip is a tie only when BOTH candidates sit this close to the
# reference max.  A wrong-history bug shifts logits by O(0.1-1).
_TIE_TOL = 0.08


def _assert_parity_mod_ties(eng, prompt, got, want):
    """got == want, or they diverge at a genuine argmax tie: at the
    first differing step both candidate tokens must score within
    _TIE_TOL of the max in a reference whole-sequence forward (v1's
    prefill step over prompt + the agreed tokens).  After a tie flip
    the continuations legitimately diverge, so comparison stops."""
    if got == want:
        return True
    t = next(i for i, (g, w) in enumerate(zip(got, want)) if g != w)
    seq = np.concatenate([np.asarray(prompt, np.int32),
                          np.asarray(want[:t], np.int32)])
    toks = np.zeros((1, eng._bucket_len(len(seq))), np.int32)
    toks[0, :len(seq)] = seq
    logits, _ = eng.prefill(eng.params, {"tokens": jnp.asarray(toks)},
                            jnp.int32(len(seq) - 1))
    lg = np.asarray(logits, np.float32).reshape(-1)
    top = float(lg.max())
    for tok in (got[t], want[t]):
        assert top - float(lg[tok]) <= _TIE_TOL, \
            (got, want, t, tok, float(lg[tok]), top)
    return False


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_chunked_matches_whole_prompt_prefill_bf16(monkeypatch,
                                                   backend):
    """v2 (chunked) and v1 (whole-prompt B=1 prefill) serve the same
    trace token-for-token on a bf16 cache, modulo ULP-tied argmax
    flips (see module docstring) — every divergence is verified to be
    a tie against a reference whole-sequence forward."""
    monkeypatch.setenv("REPRO_KERNELS", backend)
    cfg = _cfg("bf16")
    params = _params(cfg)
    protos = _requests(cfg, MIXED_LENS, max_new=5)

    def serve(chunked):
        monkeypatch.setenv("REPRO_CHUNKED_PREFILL",
                           "1" if chunked else "0")
        reqs = [Request(rid=r.rid, prompt=r.prompt.copy(), max_new=5)
                for r in protos]
        eng = Engine(cfg, params, num_slots=3, max_len=64,
                     chunk_tokens=CHUNK)
        assert eng.chunked == chunked
        eng.run(reqs, log=None)
        assert all(r.done and len(r.out) == 5 for r in reqs)
        if chunked:
            assert eng.prefill_calls == 0
            assert eng.chunked_requests == len(reqs)
            # 1 + 1 + 2 + 4 chunks for MIXED_LENS under CHUNK=8
            assert eng.chunk_prefill_steps == 8
        else:
            assert eng.prefill_calls == len(reqs)
        return eng, [r.out for r in reqs]

    eng, got = serve(chunked=True)
    _, want = serve(chunked=False)
    exact = sum(_assert_parity_mod_ties(eng, p.prompt, g, w)
                for p, g, w in zip(protos, got, want))
    assert exact >= len(protos) - 1, (got, want)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_chunked_fp8_is_batch_composition_independent(monkeypatch,
                                                      backend):
    """fp8-cache chunked serving is exact w.r.t. batch composition:
    mixed-depth concurrent serving matches per-request solo serving
    token-for-token (each chunk reads only its own request's pages)."""
    monkeypatch.setenv("REPRO_KERNELS", backend)
    cfg = _cfg("fp8")
    params = _params(cfg)
    reqs = _requests(cfg, MIXED_LENS, max_new=4)
    eng = Engine(cfg, params, num_slots=3, max_len=64,
                 chunk_tokens=CHUNK)
    assert eng.chunked
    eng.run(reqs, log=None)
    solo = _solo(cfg, params, reqs, max_len=64, chunk_tokens=CHUNK)
    for r, expect in zip(reqs, solo):
        assert r.out == expect, (r.rid, r.out, expect)


@pytest.mark.parametrize("placement", ["float", "identity"])
def test_chunked_placements_agree(monkeypatch, placement):
    """The identity-placement chunk path (detached one-row staging
    cache) and the float path (pool scatter through the block table)
    produce the same tokens as v1 whole-prompt prefill."""
    monkeypatch.setenv("REPRO_PAGED_PLACEMENT", placement)
    cfg = _cfg("bf16")
    params = _params(cfg)

    def serve(chunked):
        monkeypatch.setenv("REPRO_CHUNKED_PREFILL",
                           "1" if chunked else "0")
        reqs = _requests(cfg, [7, 19], max_new=4, seed=2)
        eng = Engine(cfg, params, num_slots=2, max_len=32,
                     chunk_tokens=CHUNK)
        assert eng.float_pages == (placement == "float")
        eng.run(reqs, log=None)
        return [r.out for r in reqs]

    assert serve(chunked=True) == serve(chunked=False)


def test_prefix_hit_suffix_chunks_match_cold():
    """A prefix hit chunk-prefills only its unshared suffix at an
    offset; its outputs must be exactly a cold serve's (bf16)."""
    cfg = _cfg("bf16")
    params = _params(cfg)
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab, size=32, dtype=np.int32)
    mk = lambda rid, tail: Request(
        rid=rid, prompt=np.concatenate(
            [prefix, rng.integers(0, cfg.vocab, size=tail,
                                  dtype=np.int32)])
        if tail else prefix.copy(), max_new=4)
    donor, partial, full = mk(0, 0), mk(1, 9), mk(2, 0)
    eng = Engine(cfg, params, num_slots=2, max_len=64, chunk_tokens=8)
    eng.run([donor], log=None)
    eng.run([partial, full], log=None)
    assert eng.prefix_hits == 2
    assert partial.prefill_skipped == 32      # partial: exact pages
    assert full.prefill_skipped == 31         # full: last token chunks
    cold = _solo(cfg, params, [partial, full], max_len=64,
                 chunk_tokens=8, prefix_cache=False)
    assert partial.out == cold[0]
    assert full.out == cold[1]


# ---------------------------------------------------------------------------
# Preemption: bitwise swap round-trip, usage admission
# ---------------------------------------------------------------------------


def test_swap_out_in_round_trip_is_bitwise():
    """swap_out -> swap_in restores the victim's pages bit-for-bit:
    payloads AND scales, every layer (fp8 cache — requantization
    would show up here as changed bytes)."""
    cfg = _cfg("fp8")
    params = _params(cfg)
    eng = Engine(cfg, params, num_slots=2, max_len=64, chunk_tokens=8)
    assert eng.preemption
    req = _requests(cfg, [21], max_new=8)[0]
    eng.submit([req])
    for _ in range(4):                        # attach + a few decodes
        eng.step()
    assert not req.done
    row = eng.kv.rows.index(req.rid)
    pages_before = list(eng.kv.allocator.table(req.rid).pages)

    def snap(pages):
        out = {}
        for name, seg in eng.kv.caches.items():
            if seg is None:
                continue
            for leaf in ("k", "v", "k_scale", "v_scale"):
                buf = getattr(seg, leaf, None) if hasattr(seg, leaf) \
                    else None
                if buf is not None:
                    out[(name, leaf)] = np.asarray(buf[:, pages])
        return out

    before = snap(pages_before)
    bundle = eng.kv.swap_out(row)
    assert req.rid not in eng.kv.rows
    eng.kv.swap_in(bundle, req.prompt_len + req.max_new - 1)
    after = snap(eng.kv.allocator.table(req.rid).pages)
    assert before.keys() == after.keys() and len(before) > 0
    for key in before:
        assert np.array_equal(before[key], after[key]), key


def test_preemption_resumes_with_solo_outputs():
    """A pool far below worst-case reservations: usage admission
    packs requests concurrently, growth preempts victims to host, and
    every request still finishes with exactly its solo tokens."""
    cfg = _cfg("bf16")
    params = _params(cfg)
    reqs = _requests(cfg, [12, 12, 12, 12], max_new=40, seed=4)
    # worst case is 4 pages/request; 6 pages can't hold two worst
    # cases, but usage admission (1 page prompt + 1 headroom) packs 3
    eng = Engine(cfg, params, num_slots=3, max_len=64, chunk_tokens=8,
                 num_pages=6, prefix_cache=False)
    assert eng.preemption
    eng.run(reqs, log=None)
    assert all(r.done and len(r.out) == 40 for r in reqs)
    assert eng.preemptions > 0 and eng.swap_ins == eng.preemptions
    al = eng.kv.allocator
    assert al.free_pages == al.num_pages      # everything released
    solo = _solo(cfg, params, reqs, max_len=64, chunk_tokens=8,
                 prefix_cache=False)
    for r, expect in zip(reqs, solo):
        assert r.out == expect, (r.rid, r.out, expect)


def test_usage_admission_outpacks_v1_reservation(monkeypatch):
    """On the same minimal pool, v1's worst-case reservation can only
    serve one request at a time; v2's usage-based admission runs them
    concurrently (observed: a multi-row decode batch ever exists)."""
    cfg = _cfg("bf16")
    params = _params(cfg)

    def peak_rows(chunked):
        monkeypatch.setenv("REPRO_CHUNKED_PREFILL",
                           "1" if chunked else "0")
        reqs = _requests(cfg, [12, 12, 12], max_new=40, seed=5)
        eng = Engine(cfg, params, num_slots=3, max_len=64,
                     chunk_tokens=8, num_pages=6, prefix_cache=False)
        eng.submit(reqs)
        peak = 0
        while not eng._idle():
            eng.step()
            peak = max(peak, len(eng.kv.rows))
        assert all(r.done for r in reqs)
        return peak

    assert peak_rows(chunked=False) == 1      # 4-page worst case x2 > 6
    assert peak_rows(chunked=True) >= 2       # usage packs the pool


# ---------------------------------------------------------------------------
# SLO policy units (model-free)
# ---------------------------------------------------------------------------


def _clock():
    state = {"t": 0.0}

    def now():
        return state["t"]

    return state, now


def test_chunk_budget_reacts_to_slo_pressure():
    state, now = _clock()
    sched = Scheduler(clock=now, slo=SLOTargets(ttft_s=1.0,
                                                tpot_s=0.1))
    assert sched.chunk_budget() == 2          # idle default
    # a running request blowing its TPOT target shrinks the budget
    slow = Request(rid=0, prompt=np.zeros(4, np.int32), max_new=10)
    sched.submit([slow])
    sched.pop()
    sched.on_token(slow, 1)
    state["t"] = 0.3                          # 300 ms gap > 100 ms SLO
    sched.on_token(slow, 2)
    assert sched.chunk_budget() == 1
    # a queue head nearing its TTFT target boosts it (TTFT wins)
    waiting = Request(rid=1, prompt=np.zeros(4, np.int32), max_new=1)
    sched.submit([waiting])
    state["t"] += 0.6                         # waited 0.6 > 0.5*ttft
    assert sched.chunk_budget() == 4


def test_pick_victim_prefers_tpot_headroom():
    state, now = _clock()
    sched = Scheduler(clock=now, slo=SLOTargets(tpot_s=0.1))
    a, b = (Request(rid=i, prompt=np.zeros(4, np.int32), max_new=10)
            for i in range(2))
    sched.submit([a])
    state["t"] = 0.01
    sched.submit([b])
    for r, gap in ((a, 0.09), (b, 0.01)):
        sched.pop()
        sched.on_token(r, 1)
        state["t"] += gap
        sched.on_token(r, 2)
    # a runs at 90 ms/token (10 ms headroom), b at 10 ms (90 ms):
    # b tolerates the swap stall best
    assert sched.pick_victim([a, b]) is b
    assert sched.pick_victim([]) is None
    # no-history candidates tie at full headroom; latest submit loses
    c, d = (Request(rid=2 + i, prompt=np.zeros(4, np.int32),
                    max_new=10) for i in range(2))
    state["t"] = 1.0
    sched.submit([c])
    state["t"] = 2.0
    sched.submit([d])
    assert sched.pick_victim([c, d]) is d


def test_summary_reports_latency_percentiles():
    state, now = _clock()
    sched = Scheduler(clock=now)
    reqs = [Request(rid=i, prompt=np.zeros(2, np.int32), max_new=2)
            for i in range(3)]
    sched.submit(reqs)
    for i, r in enumerate(reqs):
        sched.pop()
        state["t"] = float(i + 1)             # TTFTs 1, 2, 3 s
        sched.on_token(r, 1)
        state["t"] += 0.1 * (i + 1)           # TPOTs 0.1, 0.2, 0.3 s
        sched.on_token(r, 2)
    s = sched.summary()
    assert s["p50_ttft_s"] == pytest.approx(2.0)
    assert s["p99_ttft_s"] == pytest.approx(2.98)
    assert s["p50_tpot_s"] == pytest.approx(0.2)
    assert s["p99_tpot_s"] == pytest.approx(0.298)


def test_open_loop_arrivals_honor_offsets():
    """``Request.arrival_time`` turns run() into an open-loop driver:
    a request is not submitted (TTFT clock not started) before its
    offset, and requests still finish correctly."""
    cfg = _cfg("bf16")
    params = _params(cfg)
    reqs = _requests(cfg, [6, 6], max_new=3, seed=6)
    reqs[1].arrival_time = 0.25
    eng = Engine(cfg, params, num_slots=2, max_len=32, chunk_tokens=8)
    t0 = __import__("time").monotonic()
    eng.run(reqs, log=None)
    assert all(r.done and len(r.out) == 3 for r in reqs)
    assert reqs[1].t_submit - (t0 - 0.0) >= 0.0
    # the late request's submit stamp respects its arrival offset
    assert reqs[1].t_submit >= reqs[0].t_submit + 0.25
