"""Unit + property tests for the quantization core (paper §3.1).

Property sweeps use hypothesis when installed, else the deterministic
fixed grid from tests/_hypo.py."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, st

from repro.core.formats import (
    E4M3_MAX,
    E5M2_MAX,
    MOSS_CONFIG,
    PER_TENSOR_CONFIG,
    cast_fp8,
    e8m0_decode,
    e8m0_encode,
)
from repro.core.quant import (
    model_snr_moss,
    model_snr_per_group,
    model_snr_per_tensor,
    mx_gemm,
    quant_mx,
    quant_per_group,
    quant_per_tensor,
    scheme_snr,
)


def outlier_activation(key, shape, outlier_scale=300.0, density=0.002):
    """LLM-like activation: gaussian body + strong sparse outliers."""
    k1, k2 = jax.random.split(key)
    base = jax.random.normal(k1, shape, jnp.float32)
    mask = jax.random.bernoulli(k2, density, shape)
    return base * (1.0 + outlier_scale * mask)


class TestFormats:
    def test_fp8_saturating_cast(self):
        x = jnp.array([500.0, -500.0, 1e9, -1e9], jnp.float32)
        q = cast_fp8(x, "e4m3").astype(jnp.float32)
        assert (jnp.abs(q) == E4M3_MAX).all()
        q5 = cast_fp8(jnp.array([1e9], jnp.float32), "e5m2")
        assert float(q5.astype(jnp.float32)[0]) == E5M2_MAX

    def test_e8m0_roundtrip_powers_of_two(self):
        for e in [-127, -64, -1, 0, 5, 127]:
            enc = e8m0_encode(jnp.float32(2.0 ** e))
            assert int(enc) == e
            assert float(e8m0_decode(enc)) == 2.0 ** e

    def test_e8m0_ceil_never_underestimates(self):
        # ceil => s*ss >= s_g so grouped values can never overflow
        r = jnp.asarray(np.random.default_rng(0).uniform(1e-30, 1.0, 512),
                        jnp.float32)
        ss = e8m0_decode(e8m0_encode(r))
        assert (ss * (1 + 2e-6) >= r).all()


class TestQuantizers:
    def test_mx_subscales_in_unit_interval(self):
        x = outlier_activation(jax.random.PRNGKey(0), (64, 256))
        q = quant_mx(x)
        ss = e8m0_decode(q.sexp)
        assert (ss > 0).all() and (ss <= 1.0).all()   # paper Thm 1

    def test_mx_rescues_small_groups(self):
        # a group 5 decades below amax flushes to 0 per-tensor but keeps
        # ~2% relative error under two-level microscaling
        big = jnp.linspace(100, 400, 32)
        tiny = jnp.linspace(1e-5, 1e-4, 32)
        x = jnp.concatenate([big, tiny]).reshape(1, 64)
        mx_err = jnp.abs(quant_mx(x).dequant() - x)[0, 32:]
        pt_err = jnp.abs(quant_per_tensor(x).dequant() - x)[0, 32:]
        assert float(mx_err.max() / tiny.max()) < 0.05
        assert float(pt_err.min() / tiny.min()) > 0.99   # flushed

    def test_dequant_roundtrip_relative_error(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
        for q in (quant_mx(x), quant_per_group(x), quant_per_tensor(x)):
            rel = jnp.abs(q.dequant() - x) / (jnp.abs(x) + 1e-6)
            # e4m3: 3 mantissa bits -> max rel rounding ~ 2^-3 at the
            # subnormal edge; median must be well under that
            assert float(jnp.median(rel)) < 0.05

    def test_zero_tensor_is_safe(self):
        x = jnp.zeros((32, 64))
        for q in (quant_mx(x), quant_per_group(x, 32),
                  quant_per_tensor(x)):
            assert bool(jnp.isfinite(q.dequant()).all())
            assert float(jnp.abs(q.dequant()).max()) == 0.0

    def test_tiny_gradient_tensor_no_nan(self):
        # regression: ss*s used to underflow f32 -> 0/0 NaN
        x = jax.random.normal(jax.random.PRNGKey(2), (32, 64)) * 1e-20
        q = quant_mx(x, fmt="e5m2")
        assert bool(jnp.isfinite(q.dequant()).all())

    @settings(max_examples=20, deadline=None)
    @given(rows=st.integers(1, 8), groups=st.integers(1, 8),
           scale_pow=st.integers(-20, 10))
    def test_mx_roundtrip_property(self, rows, groups, scale_pow):
        k = jax.random.PRNGKey(rows * 101 + groups)
        x = jax.random.normal(k, (rows, groups * 32)) * (2.0 ** scale_pow)
        q = quant_mx(x)
        dq = q.dequant()
        assert bool(jnp.isfinite(dq).all())
        # fp8 e4m3 relative error bound for in-range values: 2^-3.5-ish
        rel = jnp.abs(dq - x) / jnp.maximum(jnp.abs(x), 1e-30)
        big = jnp.abs(x) > (2.0 ** scale_pow) * 0.1
        assert float(jnp.where(big, rel, 0).max()) < 0.13


class TestTheorem1:
    """Paper Thm 1 under the paper's own (uniform/absolute) noise model:
    SNR_per-tensor < SNR_per-group < SNR_MOSS for outlier-bearing
    activations.  (Measured float-SNR is pinned by relative error —
    EXPERIMENTS.md discusses the numeric-format distinction.)"""

    def test_model_snr_strict_ordering(self):
        x = outlier_activation(jax.random.PRNGKey(0), (256, 1024))
        t = float(model_snr_per_tensor(x))
        g = float(model_snr_per_group(x))
        m = float(model_snr_moss(x))
        assert t < g < m, (t, g, m)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), density=st.floats(0.001, 0.01))
    def test_model_snr_ordering_property(self, seed, density):
        x = outlier_activation(jax.random.PRNGKey(seed), (64, 512),
                               density=density)
        t = float(model_snr_per_tensor(x))
        g = float(model_snr_per_group(x))
        m = float(model_snr_moss(x))
        assert t <= g + 1e-3
        assert t <= m + 1e-3     # moss >= per-tensor always

    def test_measured_snr_weak_ordering(self):
        x = outlier_activation(jax.random.PRNGKey(3), (256, 1024))
        t = float(scheme_snr(x, PER_TENSOR_CONFIG))
        m = float(scheme_snr(x, MOSS_CONFIG))
        assert m >= t - 1e-3     # po2 rescale never hurts measured SNR


class TestGemms:
    def test_mx_gemm_matches_dequant_matmul(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 32)) * 0.05
        xq, wq = quant_mx(x), quant_per_tensor(w)
        y = mx_gemm(xq, wq, out_dtype=jnp.float32)
        y_ref = xq.dequant() @ wq.dequant()
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-2, atol=1e-3)

    def test_quantized_gemm_close_to_exact(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 32)) * 0.05
        exact = x @ w
        y = mx_gemm(quant_mx(x), quant_per_tensor(w),
                    out_dtype=jnp.float32)
        rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
        assert rel < 0.1, rel
