"""Kernel-dispatch parity: the full qmm custom-VJP (forward, dx, dW)
under ``REPRO_KERNELS=interpret`` (Pallas kernels via the interpreter)
must match the pure-jnp reference path to fp8-noise tolerance for every
quantized mode.  This is the test that proves the training hot path
actually exercises the kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import (
    MOSS_CONFIG,
    PER_GROUP_CONFIG,
    PER_TENSOR_CONFIG,
)
from repro.core.linear import qmm
from repro.core.quant import (
    MxQ,
    PerTensorQ,
    quant_mx,
    quant_per_tensor,
)
from repro.kernels import dispatch

MODES = {
    "moss": MOSS_CONFIG,
    "per_group": PER_GROUP_CONFIG,
    "per_tensor": PER_TENSOR_CONFIG,
}


def _problem(m=128, k=512, n=256):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    # sparse outliers: the regime that separates the schemes
    x = x * (1 + 100.0 * jax.random.bernoulli(jax.random.PRNGKey(1),
                                              0.002, x.shape))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n),
                          jnp.float32) * 0.05
    return x, w


def _fwd_bwd(cfg, x, w, backend, monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", backend)

    def loss(x, w):
        s = jnp.max(jnp.abs(w)) / 448.0
        return jnp.sum(qmm(cfg, x, w, s) ** 2)

    val, grads = jax.value_and_grad(loss, argnums=(0, 1))(x, w)
    return float(val), grads


@pytest.mark.parametrize("mode", list(MODES))
def test_qmm_interpret_matches_ref(mode, monkeypatch):
    cfg = MODES[mode]
    x, w = _problem()
    v_ref, (gx_ref, gw_ref) = _fwd_bwd(cfg, x, w, "ref", monkeypatch)
    v_int, (gx_int, gw_int) = _fwd_bwd(cfg, x, w, "interpret", monkeypatch)
    assert abs(v_int - v_ref) <= 1e-4 * abs(v_ref)
    for g_i, g_r in ((gx_int, gx_ref), (gw_int, gw_ref)):
        rel = float(jnp.linalg.norm(g_i - g_r)
                    / (jnp.linalg.norm(g_r) + 1e-9))
        assert rel < 1e-4, (mode, rel)


@pytest.mark.parametrize("mode", list(MODES))
def test_qmm_interpret_matches_ref_ragged_shapes(mode, monkeypatch):
    """Non-block-aligned M/N/K exercise the dispatch padding layer."""
    cfg = MODES[mode]
    x, w = _problem(m=96, k=384, n=160)
    v_ref, (gx_ref, gw_ref) = _fwd_bwd(cfg, x, w, "ref", monkeypatch)
    v_int, (gx_int, gw_int) = _fwd_bwd(cfg, x, w, "interpret", monkeypatch)
    assert abs(v_int - v_ref) <= 1e-4 * abs(v_ref)
    for g_i, g_r in ((gx_int, gx_ref), (gw_int, gw_ref)):
        rel = float(jnp.linalg.norm(g_i - g_r)
                    / (jnp.linalg.norm(g_r) + 1e-9))
        assert rel < 1e-4, (mode, rel)


def test_fused_quant_matmul_residual_matches_quant_mx(monkeypatch):
    """The fused kernel's emitted residual must equal a standalone
    two-level quantization (same global scale, exponents, payload)."""
    x, w = _problem()
    wq = quant_per_tensor(w)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    y, xq = dispatch.fused_quant_matmul(x, wq, out_dtype=jnp.float32)
    q_ref = quant_mx(x)
    assert float(xq.s) == float(q_ref.s)
    assert (np.asarray(xq.sexp) == np.asarray(q_ref.sexp)).all()
    np.testing.assert_array_equal(
        np.asarray(xq.q.astype(jnp.float32)),
        np.asarray(q_ref.q.astype(jnp.float32)))
    # and the GEMM itself matches the reference composition
    from repro.core.quant import mx_gemm
    y_ref = mx_gemm(q_ref, wq, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-3)


def test_dw_kernel_matches_ref_composition(monkeypatch):
    """mx_matmul_dw (fused dequant→transpose→requant_M→GEMM) against
    the explicit reference composition with level-1 scale s_x."""
    x, _ = _problem(m=128, k=256)
    g = jax.random.normal(jax.random.PRNGKey(3), (128, 192), jnp.float32)
    xq = quant_mx(x)
    gq = quant_per_tensor(g, "e5m2")
    dw_ref = dispatch.mx_matmul_dw(xq, gq, backend="ref")
    dw_int = dispatch.mx_matmul_dw(xq, gq, backend="interpret")
    rel = float(jnp.linalg.norm(dw_int - dw_ref)
                / (jnp.linalg.norm(dw_ref) + 1e-9))
    assert rel < 1e-5, rel


def test_backend_env_is_respected_per_call(monkeypatch):
    """Flipping REPRO_KERNELS between calls must not be shadowed by a
    stale jit cache (regression for the old jit-wrapped ops)."""
    x, w = _problem(m=64, k=128, n=64)
    wq = quant_per_tensor(w)
    xq = quant_mx(x)
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    y_ref = dispatch.mx_matmul(xq, wq, out_dtype=jnp.float32)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    y_int = dispatch.mx_matmul(xq, wq, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-3)


def test_unknown_backend_rejected(monkeypatch):
    from repro.core.runtime_flags import kernel_backend

    monkeypatch.setenv("REPRO_KERNELS", "cuda")
    with pytest.raises(ValueError):
        kernel_backend()


@pytest.mark.parametrize("mode", list(MODES))
def test_train_step_runs_under_interpret(mode, monkeypatch):
    """One real train step with the kernel path active end-to-end."""
    from repro.configs.registry import get_config
    from repro.train.steps import (TrainHParams, init_train_state,
                                   make_train_step)

    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    cfg = get_config("olmo-7b", smoke=True)
    from repro.launch.train import quant_from_name
    cfg = cfg.replace(quant=quant_from_name(mode))
    hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=4)
    state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, hp))
    batch = {"tokens": jnp.zeros((2, 64), jnp.int32),
             "labels": jnp.zeros((2, 64), jnp.int32)}
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_qt_carries_mxq_semantics():
    """Doc-pin: fused path residual really is the 1.8× saving carrier —
    fp8 payload + int8 exponents, no bf16 activation retained."""
    x, w = _problem(m=64, k=128, n=64)
    wq = quant_per_tensor(w)
    _, xq = dispatch.fused_quant_matmul(x, wq, backend="ref")
    assert isinstance(xq, MxQ)
    assert xq.q.dtype == jnp.float8_e4m3fn
    assert xq.sexp.dtype == jnp.int8
    assert isinstance(wq, PerTensorQ)
