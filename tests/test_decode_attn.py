"""Fused decode-attention contract (docs/decode-attention.md):

- ref-vs-interpret parity of ``dispatch.decode_attention`` on fp8 AND
  bf16 caches across the ring states (partial, exactly-full, wrapped
  window) and GQA grouping;
- the multi-block online-softmax path against the exact oracle;
- bitwise kernel-vs-einsum equality on the bf16 cache (the einsum
  path IS the ref oracle — one source of truth);
- the acceptance assertion: the fp8-cache decode jaxpr on the kernel
  path contains ZERO cache-sized dequant upcasts / dots (the
  scale-folding einsums the fused kernel removes);
- the ``REPRO_DECODE_ATTN`` escape hatch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import dispatch
from repro.kernels.decode_attn import decode_attn_pallas
from repro.models import attention as A
from repro.models.layers import init_tree
from repro.models.transformer import model_defs
from repro.train.steps import (
    make_decode_step,
    make_prefill_step,
    prequantize_params,
)


def _build_cache(cfg, batch, max_len, n_written, seed=0):
    """Write ``n_written`` positions through the real append path (ring
    roll for n_written >= C, contiguous write otherwise)."""
    k = jax.random.normal(jax.random.PRNGKey(seed),
                          (batch, n_written, cfg.n_kv, cfg.head_dim))
    v = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (batch, n_written, cfg.n_kv, cfg.head_dim))
    return A._cache_write(cfg, A.init_cache(cfg, batch, max_len), k, v)


def _q(cfg, batch, seed=2, dtype=jnp.bfloat16):
    g = cfg.n_heads // cfg.n_kv
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (batch, cfg.n_kv, g, cfg.head_dim), dtype)


# ring states: (arch, max_len, n_written) — h2o smoke is swa with
# window 64 (GQA g=2), phi3 smoke is full attention
RING_CASES = [
    ("phi3-mini-3.8b", 51, 48),     # partial ring (n_valid < C)
    ("h2o-danube-3-4b", 96, 48),    # partial window cache (C = 64)
    ("h2o-danube-3-4b", 96, 64),    # exactly full ring
    ("h2o-danube-3-4b", 96, 80),    # wrapped window (roll path, idx > C)
]


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
@pytest.mark.parametrize("arch,max_len,n_written", RING_CASES)
def test_ref_vs_interpret_parity(arch, max_len, n_written, kv_dtype):
    cfg = get_config(arch, smoke=True).replace(kv_cache_dtype=kv_dtype)
    cache = _build_cache(cfg, 2, max_len, n_written)
    q = _q(cfg, 2)
    nv = jnp.int32(n_written)
    outs = {b: dispatch.decode_attention(
        q, cache.k, cache.v, cache.k_scale, cache.v_scale, nv,
        backend=b) for b in ("ref", "interpret")}
    # single C block → the kernel replays the exact softmax in the
    # reference operation order: bitwise across backends
    assert jnp.array_equal(outs["ref"], outs["interpret"]), \
        float(jnp.abs(outs["ref"] - outs["interpret"]).max())
    assert outs["ref"].dtype == jnp.float32


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
def test_per_slot_n_valid_vector(kv_dtype):
    """The per-(batch) ``n_valid`` vector (continuous-batching
    engine): rows at different depths in ONE launch must be bitwise
    identical to per-row scalar calls, on both backends."""
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        kv_cache_dtype=kv_dtype)
    b = 3
    cache = _build_cache(cfg, b, 96, 60)
    q = _q(cfg, b)
    nv = jnp.asarray([13, 60, 37], jnp.int32)    # per-slot depths
    outs = {bk: dispatch.decode_attention(
        q, cache.k, cache.v, cache.k_scale, cache.v_scale, nv,
        backend=bk) for bk in ("ref", "interpret")}
    assert jnp.array_equal(outs["ref"], outs["interpret"]), \
        float(jnp.abs(outs["ref"] - outs["interpret"]).max())
    # each row == the scalar-n_valid call on that row alone
    for bi in range(b):
        sl = lambda a: None if a is None else a[bi:bi + 1]
        for bk in ("ref", "interpret"):
            solo = dispatch.decode_attention(
                q[bi:bi + 1], sl(cache.k), sl(cache.v),
                sl(cache.k_scale), sl(cache.v_scale),
                jnp.int32(int(nv[bi])), backend=bk)
            assert jnp.array_equal(solo[0], outs[bk][bi]), (bk, bi)


def test_gqa_head_grouping_semantics():
    """Against an independent f64 oracle (repeat kv heads, plain
    softmax) — validates the grouping convention itself, not just
    backend agreement: query head h attends through kv head h // G."""
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        kv_cache_dtype="bf16")
    b, kvh, dh = 2, cfg.n_kv, cfg.head_dim
    g = cfg.n_heads // kvh
    cache = _build_cache(cfg, b, 40, 24)
    q = _q(cfg, b)
    out = dispatch.decode_attention(q, cache.k, cache.v, None, None,
                                    jnp.int32(24), backend="ref")
    kf = np.asarray(cache.k, np.float64)[:, :, :24]   # (B,KV,24,Dh)
    vf = np.asarray(cache.v, np.float64)[:, :, :24]
    qf = np.asarray(q, np.float64)
    for bi in range(b):
        for h in range(cfg.n_heads):
            kv = h // g
            s = (qf[bi, kv, h % g] @ kf[bi, kv].T) * dh ** -0.5
            w = np.exp(s - s.max())
            w /= w.sum()
            expect = w @ vf[bi, kv]
            got = np.asarray(out, np.float64)[bi, kv, h % g]
            np.testing.assert_allclose(got, expect, atol=2e-2)


def test_multi_block_online_softmax():
    """C split across several blocks (with a ragged trailing block)
    switches the kernel to the online rescaling — matching the exact
    oracle at the bf16 combine-weight noise floor (both paths round
    the softmax weights to bf16 for the MXU per the ``mm`` operand
    convention; online rounds the unnormalized per-block ``p``, the
    oracle the final ``w``, so agreement is ~bf16-eps, not bitwise)."""
    b, kvh, g, c, dh = 2, 2, 8, 160, 32
    kf = jax.random.normal(jax.random.PRNGKey(0), (b, kvh, c, dh))
    vf = jax.random.normal(jax.random.PRNGKey(1), (b, kvh, c, dh))
    q = jax.random.normal(jax.random.PRNGKey(2), (b, kvh, g, dh),
                          jnp.bfloat16)
    nv = jnp.int32(150)                       # masked tail inside a block
    for quantized in (True, False):
        if quantized:
            k, ks = A._quant_kv(kf)
            v, vs = A._quant_kv(vf)
        else:
            k, v = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
            ks = vs = None
        ref = dispatch.decode_attention(q, k, v, ks, vs, nv,
                                        backend="ref")
        multi = decode_attn_pallas(q, k, v, ks, vs, nv.reshape(1),
                                   sm_scale=dh ** -0.5, bc=64,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(multi), np.asarray(ref),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("kv_dtype", ["bf16", "fp8"])
def test_kernel_vs_einsum_through_attention(monkeypatch, kv_dtype):
    """End to end through ``_decode_attention``: the kernel path
    (REPRO_KERNELS=interpret) against the REPRO_DECODE_ATTN=einsum
    escape hatch — bitwise on the bf16 cache (the ISSUE contract; the
    fp8 cache happens to match bitwise too on this fixture)."""
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        kv_cache_dtype=kv_dtype)
    cache = _build_cache(cfg, 2, 96, 70)
    q = jax.random.normal(jax.random.PRNGKey(3),
                          (2, 1, cfg.n_heads, cfg.head_dim),
                          jnp.bfloat16)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.delenv("REPRO_DECODE_ATTN", raising=False)
    out_k = A._decode_attention(cfg, q, cache, jnp.int32(70))
    monkeypatch.setenv("REPRO_DECODE_ATTN", "einsum")
    out_e = A._decode_attention(cfg, q, cache, jnp.int32(70))
    if kv_dtype == "bf16":
        assert jnp.array_equal(out_k, out_e)
    else:
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_e, np.float32),
            rtol=1e-5, atol=1e-5)


def test_fp8_decode_jaxpr_has_no_dequant_einsums(monkeypatch):
    """The acceptance contract: on the kernel path the fp8-cache decode
    jaxpr contains ZERO cache-sized fp8 dequant upcasts and ZERO
    cache-sized dot_generals — the scale-folding einsum path shows
    both.  (REPRO_KERNELS=interpret so the kernel path traces on CPU;
    the pallas_call interior is excluded — it reads the e4m3 payload.)"""
    from repro.core.introspect import (
        count_dot_general_over,
        count_fp8_dequant_upcasts,
        count_primitive,
        kv_cache_slice_sizes,
    )

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    assert cfg.kv_cache_dtype == "fp8"
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab)
    pq = prequantize_params(cfg, params)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales))
    _, caches = pre(pq.qweights, {"tokens": toks})
    sizes = kv_cache_slice_sizes(cfg, 2, 16)

    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.delenv("REPRO_DECODE_ATTN", raising=False)
    jx_k = jax.make_jaxpr(make_decode_step(cfg, scales=pq.scales))(
        pq.qweights, caches, toks[:, :1])
    monkeypatch.setenv("REPRO_DECODE_ATTN", "einsum")
    jx_e = jax.make_jaxpr(make_decode_step(cfg, scales=pq.scales))(
        pq.qweights, caches, toks[:, :1])

    assert count_fp8_dequant_upcasts(jx_e, sizes) > 0    # einsum: dequant
    assert count_dot_general_over(jx_e, sizes) > 0       # cache-sized dots
    assert count_fp8_dequant_upcasts(jx_k, sizes) == 0   # kernel: never
    assert count_dot_general_over(jx_k, sizes) == 0
    # under interpret the linear GEMMs are pallas_calls on BOTH paths;
    # the kernel path adds the fused decode-attention launch on top
    assert count_primitive(jx_k, "pallas_call") > \
        count_primitive(jx_e, "pallas_call")

    # and the two graphs still agree numerically
    monkeypatch.delenv("REPRO_DECODE_ATTN")
    dec_k = jax.jit(make_decode_step(cfg, scales=pq.scales))
    lo_k, _ = dec_k(pq.qweights, caches, toks[:, :1])
    monkeypatch.setenv("REPRO_DECODE_ATTN", "einsum")
    dec_e = jax.jit(make_decode_step(cfg, scales=pq.scales))
    lo_e, _ = dec_e(pq.qweights, caches, toks[:, :1])
    np.testing.assert_allclose(np.asarray(lo_k, np.float32),
                               np.asarray(lo_e, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_decode_attn_flag_validation(monkeypatch):
    from repro.core.runtime_flags import decode_attn_path

    monkeypatch.delenv("REPRO_DECODE_ATTN", raising=False)
    assert decode_attn_path() == "kernel"
    monkeypatch.setenv("REPRO_DECODE_ATTN", "einsum")
    assert decode_attn_path() == "einsum"
    monkeypatch.setenv("REPRO_DECODE_ATTN", "fused")
    with pytest.raises(ValueError):
        decode_attn_path()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "h2o-danube-3-4b",
                                  "recurrentgemma-2b", "stablelm-12b",
                                  "phi3.5-moe-42b-a6.6b", "minitron-8b"])
def test_kernel_path_decode_all_cache_archs(monkeypatch, arch):
    """Every cache-bearing arch decodes through the fused kernel
    (interpret backend) and agrees with the einsum path — GQA/MQA
    grouping, window/ring semantics and the MoE/hybrid assemblies all
    route through the same dispatch entry."""
    cfg = get_config(arch, smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab)
    pq = prequantize_params(cfg, params)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales))
    _, caches = pre(pq.qweights, {"tokens": toks})
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.delenv("REPRO_DECODE_ATTN", raising=False)
    lo_k, _ = jax.jit(make_decode_step(cfg, scales=pq.scales))(
        pq.qweights, caches, toks[:, :1])
    monkeypatch.setenv("REPRO_DECODE_ATTN", "einsum")
    lo_e, _ = jax.jit(make_decode_step(cfg, scales=pq.scales))(
        pq.qweights, caches, toks[:, :1])
    scale = float(jnp.abs(lo_e).max()) + 1e-6
    assert float(jnp.abs(lo_k - lo_e).max()) / scale < 1e-3


# ---------------------------------------------------------------------------
# Split-K over the context axis C (long-context decode past the
# single-block VMEM ceiling) — docs/decode-attention.md
# ---------------------------------------------------------------------------

from _hypo import given, settings, st  # noqa: E402
from repro.kernels.decode_attn import (  # noqa: E402
    MAX_SINGLE_BLOCK,
    decode_attn_paged_pallas,
)
from repro.kernels.ref import decode_attn_paged_ref  # noqa: E402


def _long_ctx(c, seed=0, b=1, kvh=2, g=8, dh=32, quantized=True):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, kvh, g, dh)), jnp.bfloat16)
    kf = jnp.asarray(rng.standard_normal((b, kvh, c, dh)))
    vf = jnp.asarray(rng.standard_normal((b, kvh, c, dh)))
    if quantized:
        k, ks = A._quant_kv(kf)
        v, vs = A._quant_kv(vf)
    else:
        k, v = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
        ks = vs = None
    return q, k, v, ks, vs


@pytest.mark.parametrize("n_valid", [
    2500,                    # partial: masked tail inside the last block
    MAX_SINGLE_BLOCK + 512,  # exactly the context depth (C == 2560)
    4000,                    # wrapped ring: idx past C clamps to C
])
@pytest.mark.parametrize("quantized", [True, False])
def test_split_k_contiguous_past_single_block_ceiling(n_valid,
                                                      quantized):
    """C > MAX_SINGLE_BLOCK auto-selects the online split-K grid
    ((B, KV, n_c) with revisiting-free accumulation) — matching an
    explicit single-block launch of the SAME kernel at the bf16
    combine-weight noise floor.  Before split-K these contexts needed
    the einsum fallback (cache-sized dequant); now the default ``bc``
    covers them."""
    c = MAX_SINGLE_BLOCK + 512
    q, k, v, ks, vs = _long_ctx(c, quantized=quantized)
    nv = jnp.asarray([n_valid], jnp.int32)
    multi = decode_attn_pallas(q, k, v, ks, vs, nv, sm_scale=32 ** -0.5,
                               interpret=True)       # bc -> MULTI_BLOCK
    single = decode_attn_pallas(q, k, v, ks, vs, nv,
                                sm_scale=32 ** -0.5, bc=c,
                                interpret=True)      # one exact block
    np.testing.assert_allclose(np.asarray(multi), np.asarray(single),
                               rtol=5e-3, atol=5e-3)
    # and both agree with the einsum oracle
    ref = dispatch.decode_attention(q, k, v, ks, vs, nv, backend="ref")
    np.testing.assert_allclose(np.asarray(multi), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("quantized", [True, False])
def test_split_k_paged_online_past_single_block_ceiling(quantized):
    """The paged kernel past C = MAX_SINGLE_BLOCK: the per-page grid
    switches from gather-then-exact-softmax to the online split-K
    accumulation (one page visited per step, never revisited, no
    (rows, C) VMEM scratch) — against the gather-pages einsum oracle
    at the combine-weight noise floor."""
    t, n_p = 256, 10                       # C = 2560 > 2048
    c = t * n_p
    q, k, v, ks, vs = _long_ctx(c, seed=3, quantized=quantized)
    # identity block table, one slot; partial depth in the last page
    bt = jnp.arange(n_p, dtype=jnp.int32).reshape(1, n_p)
    pool = lambda a: (None if a is None else
                      a[0].reshape(a.shape[1], n_p, t,
                                   *a.shape[3:]).swapaxes(0, 1))
    pk, pv, pks, pvs = pool(k), pool(v), pool(ks), pool(vs)
    for n_valid in (c, c - t // 2):
        nv = jnp.asarray([n_valid], jnp.int32)
        out = decode_attn_paged_pallas(q, pk, pv, pks, pvs, nv, bt,
                                       sm_scale=32 ** -0.5,
                                       interpret=True)
        ref = decode_attn_paged_ref(q, pk, pv, pks, pvs, nv, bt,
                                    sm_scale=32 ** -0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-3, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(c=st.sampled_from([64, 96, 130, 192, 256]),
       bc=st.sampled_from([32, 64, 128]),
       edge=st.integers(0, 5))
def test_split_k_block_boundary_property(c, bc, edge):
    """Property sweep over (C, block size, n_valid) boundary
    geometries: n_valid at 1, one-off-block edges, the last block's
    start and the full/overfull depths — the per-block masking and
    online rescaling must agree with the oracle whatever the block
    decomposition."""
    boundary = [1, bc - 1, bc, bc + 1, c - 1, c + 7][edge]
    n_valid = max(1, min(boundary, c + 7))
    q, k, v, ks, vs = _long_ctx(c, seed=c + bc + edge)
    nv = jnp.asarray([n_valid], jnp.int32)
    got = decode_attn_pallas(q, k, v, ks, vs, nv, sm_scale=32 ** -0.5,
                             bc=bc, interpret=True)
    ref = dispatch.decode_attention(q, k, v, ks, vs, nv, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)
