"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
ref.py oracles, with hypothesis shape/dtype sweeps (fixed-grid sweep
when hypothesis is not installed — see tests/_hypo.py)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, st

from repro.core.quant import quant_mx, quant_per_group, quant_per_tensor
from repro.kernels import ops, ref
from repro.kernels.group_gemm import group_gemm_pallas
from repro.kernels.mx_gemm import mx_gemm_pallas
from repro.kernels.mx_quant import mx_quant_pallas


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype) * scale


class TestMxQuantKernel:
    @settings(max_examples=8, deadline=None)
    @given(m=st.sampled_from([128, 256]), k=st.sampled_from([512, 1024]),
           fmt=st.sampled_from(["e4m3", "e5m2"]))
    def test_matches_ref(self, m, k, fmt):
        x = _rand(m * 7 + k, (m, k))
        s = ref.global_scale_ref(x, fmt)
        q_p, e_p = mx_quant_pallas(x, s, fmt=fmt, interpret=True,
                                   bm=128, bk=256)
        q_r, e_r = ref.mx_quant_ref(x, s, fmt)
        assert (np.asarray(e_p) == np.asarray(e_r)).all()
        np.testing.assert_array_equal(
            np.asarray(q_p.astype(jnp.float32)),
            np.asarray(q_r.astype(jnp.float32)))

    def test_outlier_tensor(self):
        x = _rand(0, (128, 512))
        x = x.at[3, 100].set(1e4)
        s = ref.global_scale_ref(x)
        q_p, e_p = mx_quant_pallas(x, s, interpret=True)
        q_r, e_r = ref.mx_quant_ref(x, s)
        assert (np.asarray(e_p) == np.asarray(e_r)).all()

    def test_bf16_input(self):
        x = _rand(1, (128, 512), jnp.bfloat16)
        s = ref.global_scale_ref(x)
        q_p, e_p = mx_quant_pallas(x, s, interpret=True)
        q_r, e_r = ref.mx_quant_ref(x, s)
        assert (np.asarray(e_p) == np.asarray(e_r)).all()


class TestMxGemmKernel:
    @settings(max_examples=8, deadline=None)
    @given(m=st.sampled_from([128, 256]), n=st.sampled_from([128, 256]),
           k=st.sampled_from([512, 1024]))
    def test_matches_ref(self, m, n, k):
        x = _rand(m + n, (m, k))
        w = _rand(k, (k, n), scale=0.05)
        xq = quant_mx(x)
        wq = quant_per_tensor(w)
        acc_p = mx_gemm_pallas(xq.q, xq.sexp, wq.q, interpret=True,
                               bm=128, bn=128, bk=256)
        acc_r = ref.mx_gemm_ref(xq.q, xq.sexp, wq.q)
        np.testing.assert_allclose(np.asarray(acc_p), np.asarray(acc_r),
                                   rtol=1e-5, atol=1e-2 * float(
                                       jnp.abs(acc_r).max()) * 1e-3)

    def test_block_shape_sweep(self):
        x = _rand(7, (256, 1024))
        w = _rand(8, (1024, 256), scale=0.05)
        xq, wq = quant_mx(x), quant_per_tensor(w)
        ref_acc = ref.mx_gemm_ref(xq.q, xq.sexp, wq.q)
        for bm, bn, bk in [(128, 128, 512), (256, 128, 1024),
                           (128, 256, 128), (64, 64, 32)]:
            acc = mx_gemm_pallas(xq.q, xq.sexp, wq.q, bm=bm, bn=bn,
                                 bk=bk, interpret=True)
            np.testing.assert_allclose(
                np.asarray(acc), np.asarray(ref_acc), rtol=1e-5,
                atol=abs(float(jnp.abs(ref_acc).max())) * 1e-5)


class TestGroupGemmKernel:
    @settings(max_examples=6, deadline=None)
    @given(m=st.sampled_from([128, 256]), n=st.sampled_from([128]),
           k=st.sampled_from([512, 1024]),
           bk=st.sampled_from([128, 256]))
    def test_matches_ref(self, m, n, k, bk):
        x = _rand(m * 3 + k, (m, k))
        w = _rand(k + 1, (k, n), scale=0.05)
        xq = quant_per_group(x, 128)
        wq = quant_per_tensor(w)
        acc_p = group_gemm_pallas(xq.q, xq.s, wq.q, bk=bk,
                                  interpret=True)
        acc_r = ref.group_gemm_ref(xq.q, xq.s, wq.q)
        np.testing.assert_allclose(
            np.asarray(acc_p), np.asarray(acc_r), rtol=1e-4,
            atol=abs(float(jnp.abs(acc_r).max())) * 1e-5)


class TestFusedQuantGemmKernel:
    """mx_fused: quantize+GEMM in one kernel == quant_mx ∘ mx_gemm_ref."""

    @settings(max_examples=6, deadline=None)
    @given(m=st.sampled_from([128, 256]), n=st.sampled_from([128, 256]),
           k=st.sampled_from([512, 1024]),
           fmt=st.sampled_from(["e4m3", "e5m2"]))
    def test_matches_quant_then_gemm(self, m, n, k, fmt):
        from repro.kernels.mx_fused import fused_quant_gemm_pallas

        x = _rand(m * 5 + n + k, (m, k))
        w = _rand(k + 9, (k, n), scale=0.05)
        wq = quant_per_tensor(w)
        s = ref.global_scale_ref(x, fmt)
        acc, q, e = fused_quant_gemm_pallas(x, s, wq.q, fmt=fmt,
                                            interpret=True, bk=256)
        q_r, e_r = ref.mx_quant_ref(x, s, fmt)
        assert (np.asarray(e) == np.asarray(e_r)).all()
        np.testing.assert_array_equal(
            np.asarray(q.astype(jnp.float32)),
            np.asarray(q_r.astype(jnp.float32)))
        acc_r = ref.mx_gemm_ref(q_r, e_r, wq.q)
        np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_r),
                                   rtol=1e-5,
                                   atol=float(jnp.abs(acc_r).max()) * 1e-5)


class TestDwGemmKernel:
    """mx_bwd: fused dequant→transpose→requant_M→GEMM against the
    explicit composition with unit level-1 scale (it cancels)."""

    @settings(max_examples=6, deadline=None)
    @given(m=st.sampled_from([128, 256]), n=st.sampled_from([128]),
           k=st.sampled_from([256, 512]))
    def test_matches_requant_composition(self, m, n, k):
        from repro.core.quant import MxQ, PerTensorQ, mx_gemm
        from repro.kernels.mx_bwd import mx_dw_gemm_pallas

        x = _rand(m + 2 * k, (m, k))
        g = _rand(m + 3 * n, (m, n), scale=0.1)
        xq = quant_mx(x)
        gq = quant_per_tensor(g, "e5m2")
        acc_p = mx_dw_gemm_pallas(xq.q, xq.sexp, gq.q, interpret=True,
                                  bko=128)
        x_unit = MxQ(xq.q, xq.sexp, jnp.float32(1.0)).dequant()
        xt = quant_mx(x_unit.T, 32, "e4m3",
                      global_scale=jnp.float32(1.0))
        acc_r = mx_gemm(xt, PerTensorQ(gq.q, jnp.float32(1.0)),
                        out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(acc_p), np.asarray(acc_r),
                                   rtol=1e-5,
                                   atol=float(jnp.abs(acc_r).max()) * 1e-5)


class TestOpsDispatch:
    def test_end_to_end_linear_close_to_exact(self):
        x = _rand(0, (256, 1024))
        w = _rand(1, (1024, 512), scale=0.03)
        y = ops.moss_linear(x, w, jnp.float32)
        exact = x @ w
        rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
        assert rel < 0.1

    def test_interpret_equals_ref_mode(self, monkeypatch):
        x = _rand(3, (128, 512))
        w = _rand(4, (512, 128), scale=0.05)
        monkeypatch.setenv("REPRO_KERNELS", "ref")
        y_ref = ops.moss_linear(x, w, jnp.float32)
        monkeypatch.setenv("REPRO_KERNELS", "interpret")
        y_int = ops.moss_linear(x, w, jnp.float32)
        np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-4)
