"""End-to-end behaviour tests for the MOSS FP8 training framework."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ASSIGNED, get_config
from repro.launch.train import train


def test_end_to_end_moss_training_run(tmp_path):
    """The paper's core claim, end to end at smoke scale: an FP8-MOSS
    training run is stable and learns."""
    _, hist = train("olmo-7b", steps=40, batch=8, seq=64, quant="moss",
                    ckpt_dir=str(tmp_path / "ck"), log=lambda *a: None)
    losses = [l for _, l in hist]
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_registry_covers_all_assigned_archs():
    assert len(ASSIGNED) == 10
    for arch in ASSIGNED:
        cfg = get_config(arch)
        smoke = get_config(arch, smoke=True)
        assert cfg.n_layers >= smoke.n_layers
        assert cfg.name == smoke.name


def test_public_kernel_api():
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(0), (128, 256))
    q, e, s = ops.mx_quantize(x)
    assert q.dtype == jnp.float8_e4m3fn and e.dtype == jnp.int8
    assert q.shape == x.shape and e.shape == (128, 8)
