"""Serving correctness: prefill+decode must reproduce teacher-forced
logits (KV cache / recurrent state integrity), in bf16 for exactness —
plus the pre-quantized serving contract (docs/serving.md): build-time
fp8 weights are bitwise-identical to in-graph quantization, and the
decode graph contains zero weight quantize / weight max-reduction ops.

The teacher-forcing tests pin ``kv_cache_dtype="bf16"`` (they check
cache plumbing exactness); the serving *default* is the fp8 cache,
covered by the tolerance and default-resolution tests below."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.formats import (
    BF16_CONFIG,
    MOSS_CONFIG,
    PER_GROUP_CONFIG,
    PER_TENSOR_CONFIG,
)
from repro.models.layers import init_tree, quant_mask_tree, wrap_qt_nojit
from repro.models.transformer import forward, model_defs
from repro.train.steps import (
    make_decode_step,
    make_prefill_step,
    prequantize_params,
    serve_weight_scales,
)

ARCHS = ["phi3-mini-3.8b", "h2o-danube-3-4b", "rwkv6-3b",
         "recurrentgemma-2b", "deepseek-v2-lite-16b", "stablelm-12b",
         "phi3.5-moe-42b-a6.6b", "minitron-8b"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    # capacity_factor high so MoE archs drop no tokens in train mode
    # (decode's dense-experts path is dropless by construction)
    cfg = get_config(arch, smoke=True).replace(quant=BF16_CONFIG,
                                               capacity_factor=8.0,
                                               kv_cache_dtype="bf16")
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    B, S, EXTRA = 2, 48, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + EXTRA), 0,
                              cfg.vocab)
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    full, _, _ = forward(cfg, cfg.quant, qp, {"tokens": toks},
                         mode="train")
    scale = float(jnp.abs(full).max()) + 1e-6

    pre = jax.jit(make_prefill_step(cfg, max_len=S + EXTRA))
    dec = jax.jit(make_decode_step(cfg))
    last, caches = pre(params, {"tokens": toks[:, :S]})
    assert float(jnp.abs(last[:, -1] - full[:, S - 1]).max()) / scale \
        < 1e-4
    # MoE archs: decode uses the dropless dense-experts combine while
    # train mode dispatches — bf16 path-order noise is larger there
    tol = 0.2 if get_config(arch).n_experts else 0.1
    for i in range(EXTRA):
        lo, caches = dec(params, caches, toks[:, S + i:S + i + 1])
        err = float(jnp.abs(lo[:, 0] - full[:, S + i]).max()) / scale
        assert err < tol, (i, err)


def test_swa_ring_cache_window_equivalence():
    """With a ring cache of size `window`, decoding past the window must
    match a fresh prefill truncated to the window."""
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        quant=BF16_CONFIG, window=32, kv_cache_dtype="bf16")
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                              cfg.vocab)
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    full, _, _ = forward(cfg, cfg.quant, qp, {"tokens": toks},
                         mode="train")
    pre = jax.jit(make_prefill_step(cfg, max_len=64))
    dec = jax.jit(make_decode_step(cfg))
    _, caches = pre(params, {"tokens": toks[:, :48]})
    for i in range(8):
        lo, caches = dec(params, caches, toks[:, 48 + i:49 + i])
        scale = float(jnp.abs(full).max())
        err = float(jnp.abs(lo[:, 0] - full[:, 48 + i]).max()) / scale
        assert err < 0.1, (i, err)


def test_fp8_kv_cache_accuracy():
    """fp8 KV cache (beyond-paper): decode attention within ~5% of the
    bf16 cache — the per-(token, head) E4M3 noise floor."""
    import jax.numpy as jnp
    from repro.models import attention as A

    cfg8 = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="fp8")
    cfgb = cfg8.replace(kv_cache_dtype="bf16")
    k = jax.random.normal(jax.random.PRNGKey(0),
                          (2, 48, cfg8.n_kv, cfg8.head_dim))
    v = jax.random.normal(jax.random.PRNGKey(1),
                          (2, 48, cfg8.n_kv, cfg8.head_dim))
    q = jax.random.normal(jax.random.PRNGKey(2),
                          (2, 1, cfg8.n_heads, cfg8.head_dim),
                          jnp.bfloat16)
    c8 = A._cache_write(cfg8, A.init_cache(cfg8, 2, 51), k, v)
    cb = A._cache_write(cfgb, A.init_cache(cfgb, 2, 51), k, v)
    o8 = A._decode_attention(cfg8, q, c8, jnp.int32(48))
    ob = A._decode_attention(cfgb, q, cb, jnp.int32(48))
    rel = float(jnp.abs(o8.astype(jnp.float32) - ob.astype(jnp.float32)
                        ).max() / jnp.abs(ob.astype(jnp.float32)).max())
    assert rel < 0.05, rel
    # payload really is 1 byte/element
    assert c8.k.dtype == jnp.float8_e4m3fn


def test_server_continuous_batching():
    from repro.launch.serve import Request, Server

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 16,
                                               dtype=np.int32),
                    max_new=6) for i in range(5)]
    srv = Server(cfg, params, batch_slots=2, max_len=32)
    assert srv.prequant is not None          # quantized recipe -> prequant
    assert srv.params is srv.prequant.qweights
    out = srv.run(reqs, log=lambda *a: None)
    assert all(len(r.out) == 6 for r in out)
    assert all(r.done for r in out)


# ---------------------------------------------------------------------------
# Pre-quantized serving stack (docs/serving.md)
# ---------------------------------------------------------------------------

QUANT_MODES = {"per_tensor": PER_TENSOR_CONFIG,
               "per_group": PER_GROUP_CONFIG,
               "moss": MOSS_CONFIG}


def _serving_fixture(mode, arch="phi3-mini-3.8b"):
    cfg = get_config(arch, smoke=True).replace(quant=QUANT_MODES[mode],
                                               kv_cache_dtype="bf16")
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab)
    return cfg, params, toks


@pytest.mark.parametrize("mode", sorted(QUANT_MODES))
def test_prequant_bitwise_parity(mode):
    """Pre-quantized prefill AND decode are bitwise identical to the
    in-graph-quantize path: build-time scales/payloads reproduce the
    exact fp8 bits the per-step quantizer would produce."""
    cfg, params, toks = _serving_fixture(mode)
    max_len = 16

    scales = serve_weight_scales(cfg, params)
    pre = jax.jit(make_prefill_step(cfg, max_len, scales=scales))
    dec = jax.jit(make_decode_step(cfg, scales=scales))
    la, ca = pre(params, {"tokens": toks})

    pq = prequantize_params(cfg, params)
    assert pq is not None
    pre_q = jax.jit(make_prefill_step(cfg, max_len, scales=pq.scales))
    dec_q = jax.jit(make_decode_step(cfg, scales=pq.scales))
    lb, cb = pre_q(pq.qweights, {"tokens": toks})
    assert jnp.array_equal(la, lb), float(jnp.abs(la - lb).max())

    for i in range(3):
        da, ca = dec(params, ca, toks[:, i:i + 1])
        db, cb = dec_q(pq.qweights, cb, toks[:, i:i + 1])
        assert jnp.array_equal(da, db), (i, float(jnp.abs(da - db).max()))


@pytest.mark.parametrize("mode", sorted(QUANT_MODES))
def test_prequant_moe_bitwise_parity(mode):
    """Same contract on an MoE arch: per-expert stacked weights get
    independent build-time scales (the vmapped decode experts and the
    grouped prefill kernel both consume the fp8 stack)."""
    cfg, params, toks = _serving_fixture(mode, arch="phi3.5-moe-42b-a6.6b")
    scales = serve_weight_scales(cfg, params)
    dec = jax.jit(make_decode_step(cfg, scales=scales))
    pq = prequantize_params(cfg, params)
    dec_q = jax.jit(make_decode_step(cfg, scales=pq.scales))
    pre = jax.jit(make_prefill_step(cfg, 16, scales=scales))
    _, ca = pre(params, {"tokens": toks})
    pre_q = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales))
    _, cb = pre_q(pq.qweights, {"tokens": toks})
    da, _ = dec(params, ca, toks[:, :1])
    db, _ = dec_q(pq.qweights, cb, toks[:, :1])
    assert jnp.array_equal(da, db), float(jnp.abs(da - db).max())


@pytest.mark.parametrize("mode", ["per_group", "per_tensor", "moss"])
def test_prequant_decode_graph_has_no_weight_quantize(mode):
    """The acceptance contract: the pre-quantized decode jaxpr contains
    ZERO weight-shaped fp8 casts (for every recipe) and, for the jit
    recipes, strictly fewer max-reductions than the in-graph path (the
    remaining reduce_max ops are activation amaxes + softmax)."""
    from repro.core.introspect import (
        count_fp8_casts,
        count_primitive,
        count_reduce_max_over,
        weight_slice_sizes,
    )

    cfg, params, toks = _serving_fixture(mode)
    scales = serve_weight_scales(cfg, params)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=scales))
    _, caches = pre(params, {"tokens": toks})
    tok1 = toks[:, :1]

    jx_no = jax.make_jaxpr(make_decode_step(cfg, scales=scales))(
        params, caches, tok1)
    pq = prequantize_params(cfg, params)
    jx_pq = jax.make_jaxpr(make_decode_step(cfg, scales=pq.scales))(
        pq.qweights, caches, tok1)

    wsizes = weight_slice_sizes(cfg)
    assert count_fp8_casts(jx_no, wsizes) > 0      # in-graph: quantizes W
    assert count_fp8_casts(jx_pq, wsizes) == 0     # prequant: never
    assert count_reduce_max_over(jx_pq, wsizes) == 0   # no weight amax
    n_no = count_primitive(jx_no, "reduce_max")
    n_pq = count_primitive(jx_pq, "reduce_max")
    if mode == "moss":
        # moss serving already supplied predicted scales — no weight
        # reductions to remove, only the casts (asserted above)
        assert n_pq == n_no
    else:
        assert n_pq < n_no, (n_pq, n_no)


def test_prequant_escape_hatch_and_bf16(monkeypatch):
    """REPRO_SERVE_PREQUANT=0 restores in-graph quantization; bf16 mode
    never pre-quantizes."""
    from repro.core.runtime_flags import serve_prequant

    monkeypatch.setenv("REPRO_SERVE_PREQUANT", "0")
    assert not serve_prequant()
    monkeypatch.delenv("REPRO_SERVE_PREQUANT")
    assert serve_prequant()
    cfg, params, _ = _serving_fixture("moss")
    assert prequantize_params(cfg.replace(quant=BF16_CONFIG), params) is None


def test_kv_cache_fp8_default_and_override(monkeypatch):
    """fp8 KV cache is the serving default; REPRO_KV_CACHE overrides in
    both directions at cache init."""
    from repro.models import attention as A

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    assert cfg.kv_cache_dtype == "fp8"
    c = A.init_cache(cfg, 2, 8)
    assert c.k.dtype == jnp.float8_e4m3fn and c.k_scale is not None

    monkeypatch.setenv("REPRO_KV_CACHE", "bf16")
    c = A.init_cache(cfg, 2, 8)
    assert c.k.dtype == jnp.bfloat16 and c.k_scale is None

    monkeypatch.setenv("REPRO_KV_CACHE", "fp8")
    c = A.init_cache(cfg.replace(kv_cache_dtype="bf16"), 2, 8)
    assert c.k.dtype == jnp.float8_e4m3fn

    monkeypatch.setenv("REPRO_KV_CACHE", "f16")
    with pytest.raises(ValueError):
        A.init_cache(cfg, 2, 8)


def test_decode_fp8_kv_within_tolerance_of_bf16():
    """End-to-end decode under the fp8 KV default stays in the same
    ballpark as the bf16-cache decode (same prequant weights, only the
    cache dtype differs).  The per-layer attention-output noise is <5%
    (test_fp8_kv_cache_accuracy); through a random-init smoke model it
    compounds, so this is a sanity bound, not a noise-floor claim."""
    cfg8 = get_config("phi3-mini-3.8b", smoke=True)
    cfgb = cfg8.replace(kv_cache_dtype="bf16")
    params = init_tree(model_defs(cfg8), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg8.vocab)
    outs = {}
    for name, cfg in [("fp8", cfg8), ("bf16", cfgb)]:
        pq = prequantize_params(cfg, params)
        pre = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales))
        dec = jax.jit(make_decode_step(cfg, scales=pq.scales))
        _, caches = pre(pq.qweights, {"tokens": toks})
        lo, _ = dec(pq.qweights, caches, toks[:, :1])
        outs[name] = lo.astype(jnp.float32)
    scale = float(jnp.abs(outs["bf16"]).max()) + 1e-6
    rel = float(jnp.abs(outs["fp8"] - outs["bf16"]).max()) / scale
    assert rel < 0.25, rel
    # and the cheap cache really was used: same argmax ordering at the
    # positions that matter for greedy sampling on this fixture
    assert float(jnp.mean((jnp.argmax(outs["fp8"], -1)
                           == jnp.argmax(outs["bf16"], -1))
                          .astype(jnp.float32))) > 0.5


# ---------------------------------------------------------------------------
# Delayed activation scales (reduction-free decode, docs/serving.md)
# ---------------------------------------------------------------------------

# tolerance of delayed-vs-JIT decode logits, relative to max|logit| of
# the JIT path, per recipe × kernel backend — recorded in the table in
# docs/serving.md.  On a random-init smoke model BOTH paths sit ~0.17
# from the bf16 reference; their mutual distance is the same fp8 noise,
# not a delayed-specific degradation (asserted by the bf16-anchored
# bound below).
DELAYED_TOL = {("per_tensor", "ref"): 0.20, ("per_tensor", "interpret"): 0.20,
               ("per_group", "ref"): 0.20, ("per_group", "interpret"): 0.20,
               ("moss", "ref"): 0.20, ("moss", "interpret"): 0.20}


def _delayed_fixture(mode, arch="phi3-mini-3.8b"):
    from repro.core.actscale import calibrate_act_scales

    cfg, params, toks = _serving_fixture(mode, arch=arch)
    pq = prequantize_params(cfg, params)
    act = calibrate_act_scales(cfg, pq.qweights, pq.scales)
    assert act, "calibration produced no scales"
    return cfg, pq, act, toks


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("mode", sorted(QUANT_MODES))
def test_delayed_decode_accuracy(mode, backend, monkeypatch):
    """Delayed activation scales change decode logits by no more than
    the recipe's fp8 noise floor: bounded against the JIT path
    directly, AND no farther from the bf16 reference than the JIT
    path is (up to 25% headroom) — delayed scaling may not degrade
    accuracy, only move within the quantization noise."""
    monkeypatch.setenv("REPRO_KERNELS", backend)
    cfg, pq, act, toks = _delayed_fixture(mode)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales))
    _, caches = pre(pq.qweights, {"tokens": toks})
    dec_j = jax.jit(make_decode_step(cfg, scales=pq.scales))
    dec_d = jax.jit(make_decode_step(cfg, scales=pq.scales,
                                     act_scales=act))
    cj = jax.tree.map(lambda x: x, caches)
    lj, _ = dec_j(pq.qweights, cj, toks[:, :1])
    ld, _ = dec_d(pq.qweights, caches, toks[:, :1])
    scale = float(jnp.abs(lj).max()) + 1e-6
    rel = float(jnp.abs(ld - lj).max()) / scale
    assert rel < DELAYED_TOL[(mode, backend)], (mode, backend, rel)

    # bf16 anchor: delayed is no farther from the unquantized
    # reference than JIT is (with headroom for noise realignment)
    cfgb = cfg.replace(quant=BF16_CONFIG)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    preb = jax.jit(make_prefill_step(cfgb, 16))
    _, cb = preb(params, {"tokens": toks})
    lb, _ = jax.jit(make_decode_step(cfgb))(params, cb, toks[:, :1])
    e_j = float(jnp.abs(lj - lb).max())
    e_d = float(jnp.abs(ld - lb).max())
    assert e_d <= e_j * 1.25 + 1e-6, (mode, backend, e_d, e_j)


def test_delayed_prefill_decode_consistency():
    """The delayed scales thread through BOTH steps: a prefill+decode
    run entirely on the delayed path matches the JIT path's argmax
    trajectory on most positions (greedy decoding survives the noise
    realignment)."""
    cfg, pq, act, toks = _delayed_fixture("moss")
    pre_d = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales,
                                      act_scales=act))
    dec_d = jax.jit(make_decode_step(cfg, scales=pq.scales,
                                     act_scales=act))
    pre_j = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales))
    dec_j = jax.jit(make_decode_step(cfg, scales=pq.scales))
    ld, cd = pre_d(pq.qweights, {"tokens": toks})
    lj, cj = pre_j(pq.qweights, {"tokens": toks})
    agree, total = 0, 0
    for i in range(3):
        ld, cd = dec_d(pq.qweights, cd, toks[:, i:i + 1])
        lj, cj = dec_j(pq.qweights, cj, toks[:, i:i + 1])
        agree += int(jnp.sum(jnp.argmax(ld, -1) == jnp.argmax(lj, -1)))
        total += ld.shape[0]
    assert agree / total > 0.5, (agree, total)


def test_delayed_escape_hatch_is_bitwise(monkeypatch):
    """REPRO_SERVE_DELAYED_ACT=0 restores the just-in-time graphs
    bitwise: the Engine built with the hatch produces exactly the
    logits of hand-built JIT steps."""
    from repro.core.runtime_flags import serve_delayed_act
    from repro.serving import Engine, Request

    monkeypatch.setenv("REPRO_SERVE_DELAYED_ACT", "0")
    assert not serve_delayed_act()
    cfg, params, toks = _serving_fixture("moss")
    eng = Engine(cfg, params, 2, max_len=32)
    assert eng.act_scales is None
    # the engine's jitted decode IS the act_scales=None graph: drive
    # both on identical inputs
    pre = jax.jit(make_prefill_step(cfg, 32, scales=eng.scales))
    _, caches = pre(eng.params, {"tokens": toks})
    c2 = jax.tree.map(lambda x: x, caches)
    dec = jax.jit(make_decode_step(cfg, scales=eng.scales))
    la, _ = dec(eng.params, caches, toks[:, :1])
    lb, _ = eng.decode(eng.params, c2, toks[:, :1])
    assert jnp.array_equal(la, lb)
    monkeypatch.delenv("REPRO_SERVE_DELAYED_ACT")
    assert serve_delayed_act()


def test_delayed_calibration_deterministic():
    """Two calibrations over the same weights produce identical scales
    (fixed prompt, fixed margin) — engine-vs-engine parity holds."""
    cfg, pq, act1, _ = _delayed_fixture("per_group")
    from repro.core.actscale import calibrate_act_scales

    act2 = calibrate_act_scales(cfg, pq.qweights, pq.scales)
    assert sorted(act1) == sorted(act2)
    for tag in act1:
        assert jnp.array_equal(act1[tag].s, act2[tag].s), tag
        if act1[tag].sub is not None:
            assert jnp.array_equal(act1[tag].sub, act2[tag].sub), tag


def test_delayed_moe_decode():
    """MoE arch end to end on the delayed path: per-expert stacked
    ActScale leaves ride the vmapped dense-expert decode."""
    cfg, pq, act, toks = _delayed_fixture(
        "moss", arch="phi3.5-moe-42b-a6.6b")
    assert any("experts" in t or "w_up" in t for t in act), sorted(act)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales,
                                    act_scales=act))
    dec = jax.jit(make_decode_step(cfg, scales=pq.scales,
                                   act_scales=act))
    _, caches = pre(pq.qweights, {"tokens": toks})
    lo, _ = dec(pq.qweights, caches, toks[:, :1])
    assert bool(jnp.all(jnp.isfinite(lo)))


# ---------------------------------------------------------------------------
# Pre-quantized tied-embedding head (recurrentgemma-2b)
# ---------------------------------------------------------------------------


def test_tied_head_prequant_bitwise_parity():
    """The build-time transposed fp8 head reproduces the per-step
    re-quantization of embeddingᵀ bitwise (amax is transpose-
    invariant), for prefill and decode."""
    cfg, params, toks = _serving_fixture("moss", arch="recurrentgemma-2b")
    assert cfg.tie_embeddings
    scales = serve_weight_scales(cfg, params)
    pq = prequantize_params(cfg, params)
    assert "head_t" in pq.qweights["embed"]
    assert pq.qweights["embed"]["head_t"].dtype == jnp.float8_e4m3fn
    # in-graph tied head (pre-head_t behavior: raw params + cached
    # scales never carry head_t, so lm_head re-quantizes embᵀ)
    pre = jax.jit(make_prefill_step(cfg, 16, scales=scales))
    dec = jax.jit(make_decode_step(cfg, scales=scales))
    la, ca = pre(params, {"tokens": toks})
    # prequant transposed head
    pre_q = jax.jit(make_prefill_step(cfg, 16, scales=pq.scales))
    dec_q = jax.jit(make_decode_step(cfg, scales=pq.scales))
    lb, cb = pre_q(pq.qweights, {"tokens": toks})
    assert jnp.array_equal(la, lb), float(jnp.abs(la - lb).max())
    for i in range(3):
        da, ca = dec(params, ca, toks[:, i:i + 1])
        db, cb = dec_q(pq.qweights, cb, toks[:, i:i + 1])
        assert jnp.array_equal(da, db), (i, float(jnp.abs(da - db).max()))


def test_tied_head_decode_graph_has_no_vocab_cast():
    """Structural contract: the prequant decode graph contains no
    vocab-sized fp8 cast (the head payload was cast at build time) —
    the in-graph path contains exactly one."""
    from repro.core.introspect import count_fp8_casts
    from repro.models.transformer import init_caches

    cfg, params, _ = _serving_fixture("moss", arch="recurrentgemma-2b")
    head_sizes = {cfg.d_model * cfg.vocab}
    caches = init_caches(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    scales = serve_weight_scales(cfg, params)
    jx_no = jax.make_jaxpr(make_decode_step(cfg, scales=scales))(
        params, caches, tok)
    assert count_fp8_casts(jx_no, head_sizes) == 1
    pq = prequantize_params(cfg, params)
    jx_pq = jax.make_jaxpr(make_decode_step(cfg, scales=pq.scales))(
        pq.qweights, caches, tok)
    assert count_fp8_casts(jx_pq, head_sizes) == 0
