"""Serving correctness: prefill+decode must reproduce teacher-forced
logits (KV cache / recurrent state integrity), in bf16 for exactness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.formats import BF16_CONFIG
from repro.models.layers import init_tree, quant_mask_tree, wrap_qt_nojit
from repro.models.transformer import forward, model_defs
from repro.train.steps import make_decode_step, make_prefill_step

ARCHS = ["phi3-mini-3.8b", "h2o-danube-3-4b", "rwkv6-3b",
         "recurrentgemma-2b", "deepseek-v2-lite-16b", "stablelm-12b",
         "phi3.5-moe-42b-a6.6b", "minitron-8b"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    # capacity_factor high so MoE archs drop no tokens in train mode
    # (decode's dense-experts path is dropless by construction)
    cfg = get_config(arch, smoke=True).replace(quant=BF16_CONFIG,
                                               capacity_factor=8.0)
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    B, S, EXTRA = 2, 48, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + EXTRA), 0,
                              cfg.vocab)
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    full, _, _ = forward(cfg, cfg.quant, qp, {"tokens": toks},
                         mode="train")
    scale = float(jnp.abs(full).max()) + 1e-6

    pre = jax.jit(make_prefill_step(cfg, max_len=S + EXTRA))
    dec = jax.jit(make_decode_step(cfg))
    last, caches = pre(params, {"tokens": toks[:, :S]})
    assert float(jnp.abs(last[:, -1] - full[:, S - 1]).max()) / scale \
        < 1e-4
    # MoE archs: decode uses the dropless dense-experts combine while
    # train mode dispatches — bf16 path-order noise is larger there
    tol = 0.2 if get_config(arch).n_experts else 0.1
    for i in range(EXTRA):
        lo, caches = dec(params, caches, toks[:, S + i:S + i + 1])
        err = float(jnp.abs(lo[:, 0] - full[:, S + i]).max()) / scale
        assert err < tol, (i, err)


def test_swa_ring_cache_window_equivalence():
    """With a ring cache of size `window`, decoding past the window must
    match a fresh prefill truncated to the window."""
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        quant=BF16_CONFIG, window=32)
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                              cfg.vocab)
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    full, _, _ = forward(cfg, cfg.quant, qp, {"tokens": toks},
                         mode="train")
    pre = jax.jit(make_prefill_step(cfg, max_len=64))
    dec = jax.jit(make_decode_step(cfg))
    _, caches = pre(params, {"tokens": toks[:, :48]})
    for i in range(8):
        lo, caches = dec(params, caches, toks[:, 48 + i:49 + i])
        scale = float(jnp.abs(full).max())
        err = float(jnp.abs(lo[:, 0] - full[:, 48 + i]).max()) / scale
        assert err < 0.1, (i, err)


def test_fp8_kv_cache_accuracy():
    """fp8 KV cache (beyond-paper): decode attention within ~5% of the
    bf16 cache — the per-(token, head) E4M3 noise floor."""
    import jax.numpy as jnp
    from repro.models import attention as A

    cfg8 = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="fp8")
    cfgb = cfg8.replace(kv_cache_dtype="bf16")
    k = jax.random.normal(jax.random.PRNGKey(0),
                          (2, 48, cfg8.n_kv, cfg8.head_dim))
    v = jax.random.normal(jax.random.PRNGKey(1),
                          (2, 48, cfg8.n_kv, cfg8.head_dim))
    q = jax.random.normal(jax.random.PRNGKey(2),
                          (2, 1, cfg8.n_heads, cfg8.head_dim),
                          jnp.bfloat16)
    c8 = A._cache_write(cfg8, A.init_cache(cfg8, 2, 51), k, v)
    cb = A._cache_write(cfgb, A.init_cache(cfgb, 2, 51), k, v)
    o8 = A._decode_attention(cfg8, q, c8, jnp.int32(48))
    ob = A._decode_attention(cfgb, q, cb, jnp.int32(48))
    rel = float(jnp.abs(o8.astype(jnp.float32) - ob.astype(jnp.float32)
                        ).max() / jnp.abs(ob.astype(jnp.float32)).max())
    assert rel < 0.05, rel
    # payload really is 1 byte/element
    assert c8.k.dtype == jnp.float8_e4m3fn


def test_server_continuous_batching():
    from repro.launch.serve import Request, Server

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    defs = model_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 16,
                                               dtype=np.int32),
                    max_new=6) for i in range(5)]
    srv = Server(cfg, params, batch_slots=2, max_len=32)
    out = srv.run(reqs, log=lambda *a: None)
    assert all(len(r.out) == 6 for r in out)
    assert all(r.done for r in out)
