"""Paged-KV continuous-batching engine contract
(docs/continuous-batching.md):

- mixed-depth parity: N requests with different prompt lengths served
  concurrently through the paged engine produce token-for-token the
  same outputs as serving each request alone — fp8 AND bf16 cache,
  ref AND interpret (kernel) backends, dense AND windowed-ring archs;
- the legacy (non-paged) Server is mixed-depth-correct too (the
  shared-``idx`` clobber fix): refilled requests with different
  prefill lengths leave incumbent slots' tokens unchanged;
- scheduler unit tests: FIFO refill order, EOS/max_new retirement,
  TTFT/TPOT stamps — model-free;
- allocator unit tests: block-table accounting, page-exhaustion
  backpressure and the raises-before-corruption guarantees;
- finished slots are retired from the decode batch (the row count
  shrinks at tail drain);
- the paged decode jaxpr keeps the fused-kernel contract: zero
  cache-sized dequant upcasts / dots with the per-slot ``n_valid``
  vector.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.formats import BF16_CONFIG
from repro.models.layers import init_tree
from repro.models.transformer import model_defs
from repro.serving import (
    Engine,
    PageAllocator,
    PageExhausted,
    Request,
    Scheduler,
    SlotCapacityExceeded,
)

# prompt lengths straddle the 16-token prefill bucket boundaries on
# purpose: 6 and 11 share a bucket, 17 takes the next one
MIXED_LENS = [6, 17, 11]


def _requests(cfg, lens, max_new=4, seed=0, rid0=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab, size=n,
                                        dtype=np.int32),
                    max_new=max_new)
            for i, n in enumerate(lens)]


def _solo_outputs(cfg, params, reqs, max_len):
    outs = []
    for r in reqs:
        solo = Request(rid=1000 + r.rid, prompt=r.prompt,
                       max_new=r.max_new)
        Engine(cfg, params, num_slots=1, max_len=max_len).run(
            [solo], log=None)
        outs.append(solo.out)
    return outs


# ---------------------------------------------------------------------------
# Mixed-depth parity — the acceptance contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_mixed_depth_parity(monkeypatch, kv_dtype, backend):
    """Concurrent requests at different depths match per-request
    single-slot serving token-for-token.  bf16 compute isolates the
    cache/engine plumbing (the MOSS recipe's batch-global activation
    amax couples rows by design — covered by the tolerance test
    below); the fp8 cache quantizes per written position, so it is
    row-independent and must stay exact too."""
    monkeypatch.setenv("REPRO_KERNELS", backend)
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype=kv_dtype)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    reqs = _requests(cfg, MIXED_LENS)
    Engine(cfg, params, num_slots=2, max_len=32).run(reqs, log=None)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    solo = _solo_outputs(cfg, params, reqs, max_len=32)
    for r, expect in zip(reqs, solo):
        assert r.out == expect, (r.rid, r.out, expect)


def test_mixed_depth_parity_windowed_ring():
    """Same contract on a sliding-window arch: per-slot ring wrap
    (depth > window) must also be batch-composition-independent."""
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="bf16", window=16)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    # depths cross the 16-token window mid-decode
    reqs = _requests(cfg, [12, 20, 7], max_new=6)
    Engine(cfg, params, num_slots=2, max_len=40).run(reqs, log=None)
    solo = _solo_outputs(cfg, params, reqs, max_len=40)
    for r, expect in zip(reqs, solo):
        assert r.out == expect, (r.rid, r.out, expect)


def test_mixed_depth_parity_recurrent_arch():
    """Recurrent state (RWKV6) integrates every prefill token, so the
    engine must prefill those families at EXACT prompt length (no
    bucket padding — padded zeros would corrupt the recurrence) and
    still match solo serving token-for-token."""
    cfg = get_config("rwkv6-3b", smoke=True).replace(quant=BF16_CONFIG)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=2, max_len=32)
    assert eng.prompt_bucket == 1          # exact-length prefill
    reqs = _requests(cfg, [6, 9, 11], max_new=4)
    eng.run(reqs, log=None)
    solo = _solo_outputs(cfg, params, reqs, max_len=32)
    for r, expect in zip(reqs, solo):
        assert r.out == expect, (r.rid, r.out, expect)


@pytest.mark.slow
def test_mixed_depth_parity_moe_and_mla():
    """The engine drives the MoE dense-decode combine and the MLA
    absorbed latent cache with per-slot depths too."""
    for arch in ("phi3.5-moe-42b-a6.6b", "deepseek-v2-lite-16b"):
        cfg = get_config(arch, smoke=True).replace(quant=BF16_CONFIG,
                                                   kv_cache_dtype="bf16")
        params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
        reqs = _requests(cfg, MIXED_LENS, max_new=3)
        Engine(cfg, params, num_slots=2, max_len=32).run(reqs, log=None)
        solo = _solo_outputs(cfg, params, reqs, max_len=32)
        for r, expect in zip(reqs, solo):
            assert r.out == expect, (arch, r.rid, r.out, expect)


def test_mixed_depth_moss_recipe_tolerance():
    """Under the MOSS serving default the level-1 activation amax is
    batch-global, so concurrent serving may legitimately diverge from
    solo serving after a few tokens — the engine must still complete
    every request and agree on the (batch-independent) prefill
    token."""
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    reqs = _requests(cfg, MIXED_LENS)
    Engine(cfg, params, num_slots=2, max_len=32).run(reqs, log=None)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    solo = _solo_outputs(cfg, params, reqs, max_len=32)
    for r, expect in zip(reqs, solo):
        assert r.out[0] == expect[0], (r.rid, r.out, expect)


# ---------------------------------------------------------------------------
# Legacy (non-paged) Server: the shared-idx clobber fix
# ---------------------------------------------------------------------------


def test_legacy_server_mixed_depth_correct():
    """Refilling a slot with a SHORTER prompt than the incumbents used
    to clobber the shared ring ``idx`` (dropping incumbent tail
    tokens).  With per-slot lengths the legacy Server matches solo
    serving token-for-token on mixed-length traces."""
    from repro.launch.serve import Server

    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="bf16")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    # slot 0 starts long (17); the refill (6) is shorter — the
    # historical bug truncated the incumbent's depth to 6
    reqs = _requests(cfg, [17, 11, 6, 14], max_new=5)
    Server(cfg, params, batch_slots=2, max_len=32).run(
        list(reqs), log=lambda *a: None)
    assert all(r.done and len(r.out) == 5 for r in reqs)
    for r in reqs:
        solo = Request(rid=1000 + r.rid, prompt=r.prompt, max_new=5)
        Server(cfg, params, batch_slots=1, max_len=32).run(
            [solo], log=lambda *a: None)
        assert r.out == solo.out, (r.rid, r.out, solo.out)


# ---------------------------------------------------------------------------
# Scheduler units (model-free)
# ---------------------------------------------------------------------------


def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


def test_scheduler_fifo_refill_order():
    sched = Scheduler(clock=_fake_clock())
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new=3)
            for i in range(4)]
    sched.submit(reqs)
    assert sched.peek() is reqs[0]
    assert [sched.pop().rid for _ in range(4)] == [0, 1, 2, 3]
    assert sched.peek() is None


def test_scheduler_retirement_and_metrics():
    sched = Scheduler(clock=_fake_clock())
    req = Request(rid=0, prompt=np.zeros(4, np.int32), max_new=3,
                  eos_id=7)
    sched.submit([req])                       # t=1
    sched.pop()
    assert not sched.on_token(req, 5)         # t=2 (first token)
    assert sched.on_token(req, 7)             # t=3: EOS retires early
    assert req.done and req.out == [5, 7]
    assert req.ttft == 1.0                    # submit t=1 -> first t=2
    assert req.tpot == 1.0                    # one gap of 1s
    # max_new retirement without EOS
    req2 = Request(rid=1, prompt=np.zeros(4, np.int32), max_new=2)
    sched.submit([req2])
    sched.pop()
    sched.on_token(req2, 1)
    assert sched.on_token(req2, 2) and req2.done
    s = sched.summary()
    assert s["requests"] == 2 and s["tokens"] == 4


def test_engine_eos_early_retirement():
    """A request whose greedy continuation hits EOS stops early and
    frees its slot for the queue."""
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="bf16")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    probe = _requests(cfg, [6], max_new=6)[0]
    Engine(cfg, params, num_slots=1, max_len=32).run([probe], log=None)
    eos = probe.out[2]       # force EOS at the 3rd generated token
    req = Request(rid=10, prompt=probe.prompt, max_new=6, eos_id=eos)
    eng = Engine(cfg, params, num_slots=1, max_len=32, eos_id=eos)
    eng.run([req], log=None)
    assert req.done and len(req.out) == 3 and req.out[-1] == eos
    assert eng.kv.allocator.free_pages == eng.kv.allocator.num_pages


# ---------------------------------------------------------------------------
# Page allocator units
# ---------------------------------------------------------------------------


def test_page_allocator_accounting():
    al = PageAllocator(num_pages=8, page_size=4, slot_tokens=32)
    bt = al.admit(owner=1, prompt_tokens=5, total_tokens=13)
    assert len(bt.pages) == 2            # ceil(5/4) allocated now
    assert bt.reserved == 4              # ceil(13/4) committed
    assert al.committed_pages == 4 and al.free_pages == 6
    al.grow(1, 9)                        # crosses into page 3
    assert len(al.table(1).pages) == 3
    al.grow(1, 9)                        # idempotent within a page
    assert len(al.table(1).pages) == 3
    assert al.can_admit(16) and not al.can_admit(17)
    assert al.release(1) == 3
    assert al.free_pages == 8 and al.committed_pages == 0


def test_page_exhaustion_raises_before_corruption():
    al = PageAllocator(num_pages=4, page_size=4, slot_tokens=32)
    al.admit(owner=1, prompt_tokens=8, total_tokens=12)   # reserves 3
    assert not al.can_admit(8)           # 2 more pages don't fit
    with pytest.raises(PageExhausted):
        al.admit(owner=2, prompt_tokens=8, total_tokens=8)
    # slot ring capacity: growing past C must raise, not wrap-clobber
    with pytest.raises(SlotCapacityExceeded):
        al.grow(1, 33)
    al.release(1)
    al.admit(owner=2, prompt_tokens=8, total_tokens=8)    # now fits


def test_engine_page_backpressure_completes():
    """A pool smaller than slots*capacity throttles admissions (head
    of queue waits for pages) but every request still completes."""
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="bf16")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    reqs = _requests(cfg, [12, 12, 12, 12], max_new=3)
    # each request reserves ceil((12+3-1)/8)=2 pages; a 2-page pool
    # forces strictly serial admission despite 2 slots
    eng = Engine(cfg, params, num_slots=2, max_len=32, page_size=8,
                 num_pages=2)
    eng.run(reqs, log=None)
    assert all(r.done and len(r.out) == 3 for r in reqs)
    assert eng.kv.allocator.free_pages == 2
    solo = _solo_outputs(cfg, params, reqs, max_len=32)
    for r, expect in zip(reqs, solo):
        assert r.out == expect


def test_engine_rejects_over_capacity_request():
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="bf16")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=1, max_len=16)
    bad = Request(rid=0, prompt=np.zeros(14, np.int32), max_new=8)
    with pytest.raises(SlotCapacityExceeded):
        eng.submit([bad])
    # a request that fits its slot but can NEVER fit the page pool is
    # rejected at submit (head-of-line FIFO would otherwise livelock)
    eng2 = Engine(cfg, params, num_slots=1, max_len=64, page_size=16,
                  num_pages=2)
    too_big = Request(rid=1, prompt=np.zeros(40, np.int32), max_new=8)
    with pytest.raises(PageExhausted):
        eng2.submit([too_big])


# ---------------------------------------------------------------------------
# Retirement shrinks the decode batch (wasted-FLOP satellite)
# ---------------------------------------------------------------------------


def test_finished_slots_leave_decode_batch():
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=BF16_CONFIG, kv_cache_dtype="bf16")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    reqs = _requests(cfg, [8, 8], max_new=3) \
        + _requests(cfg, [8], max_new=8, rid0=2)
    eng = Engine(cfg, params, num_slots=3, max_len=32)
    eng.submit(reqs)
    rows_seen = []
    while eng.sched.queue or eng.kv.rows:
        eng.step()
        rows_seen.append(len(eng.kv.rows))
    assert all(r.done for r in reqs)
    # the two short requests retire while the long one keeps decoding:
    # the batch fills to three rows (admission is chunk-budgeted, so
    # not necessarily on the first step), then shrinks to one, to zero
    assert 3 in rows_seen and 1 in rows_seen
    assert eng.kv.caches is None and eng.kv.rows == []


# ---------------------------------------------------------------------------
# The fused-kernel decode contract survives the per-slot generalization
# ---------------------------------------------------------------------------


def test_paged_decode_jaxpr_keeps_kernel_contract(monkeypatch):
    """The per-slot decode jaxpr (vector idx / n_valid) still contains
    ZERO cache-sized fp8 dequant upcasts and ZERO cache-sized dots on
    the kernel path (core/introspect.py counters)."""
    from repro.core.introspect import (
        count_dot_general_over,
        count_fp8_dequant_upcasts,
        count_primitive,
        kv_cache_slice_sizes,
    )
    from repro.train.steps import make_decode_step

    cfg = get_config("phi3-mini-3.8b", smoke=True)   # fp8 cache default
    assert cfg.kv_cache_dtype == "fp8"
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=2, max_len=16)
    eng.submit(_requests(cfg, [6, 9], max_new=4))
    eng.step()                       # both admitted, one decode ran
    caches = eng.kv.caches
    tok1 = jnp.zeros((2, 1), jnp.int32)
    sizes = kv_cache_slice_sizes(cfg, 2, 16)

    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.delenv("REPRO_DECODE_ATTN", raising=False)
    jx_k = jax.make_jaxpr(make_decode_step(cfg, scales=eng.scales))(
        eng.params, caches, tok1)
    monkeypatch.setenv("REPRO_DECODE_ATTN", "einsum")
    jx_e = jax.make_jaxpr(make_decode_step(cfg, scales=eng.scales))(
        eng.params, caches, tok1)

    assert count_fp8_dequant_upcasts(jx_e, sizes) > 0
    assert count_dot_general_over(jx_e, sizes) > 0
    assert count_fp8_dequant_upcasts(jx_k, sizes) == 0
    assert count_dot_general_over(jx_k, sizes) == 0
    assert count_primitive(jx_k, "pallas_call") > \
        count_primitive(jx_e, "pallas_call")
