"""Automatic scaling tests (paper §3.2, Thm 2, Fig 4, Eq 10)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autoscale import (
    init_scale_state,
    predicted_scale,
    update_scale_state,
)
from repro.core.formats import E4M3_MAX, MOSS_CONFIG, QuantConfig
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


class TestTheorem2:
    """|ΔW_t| ≤ η for AdamW (the bound automatic scaling relies on)."""

    def test_update_bounded_by_lr(self):
        key = jax.random.PRNGKey(0)
        w = {"w": jax.random.normal(key, (64, 64))}
        opt = init_opt_state(w)
        cfg = AdamWConfig(weight_decay=0.0)
        lr = 1e-2
        rng = np.random.default_rng(0)
        for t in range(25):
            # adversarial gradients: huge, sparse, sign-flipping
            g = {"w": jnp.asarray(
                rng.normal(size=(64, 64)) * 10.0 ** rng.integers(-3, 4),
                jnp.float32)}
            w_new, opt = adamw_update(cfg, w, g, opt,
                                      jnp.asarray(t, jnp.int32),
                                      jnp.float32(lr))
            delta = jnp.abs(w_new["w"] - w["w"]).max()
            # paper Eq 8: bounded by eta * (1-b1^t)/sqrt(1-b2^t) <= ~1.4eta
            bound = lr * max(1.0, (1 - 0.9 ** (t + 1))
                             / np.sqrt(1 - 0.95 ** (t + 1))) + 1e-7
            assert float(delta) <= bound * 1.01, (t, float(delta), bound)
            w = w_new

    def test_weight_growth_bound(self):
        """max|W_t| <= max|W_0| + eta*t  (the Eq 10 premise)."""
        key = jax.random.PRNGKey(1)
        w = {"w": jax.random.normal(key, (32, 32)) * 0.02}
        w0_max = float(jnp.abs(w["w"]).max())
        opt = init_opt_state(w)
        cfg = AdamWConfig(weight_decay=0.0)
        lr = 5e-3
        for t in range(30):
            g = {"w": jax.random.normal(jax.random.fold_in(key, t),
                                        (32, 32))}
            w, opt = adamw_update(cfg, w, g, opt,
                                  jnp.asarray(t, jnp.int32),
                                  jnp.float32(lr))
            assert float(jnp.abs(w["w"]).max()) <= \
                w0_max + lr * (t + 1) * 1.4 + 1e-6


class TestAutomaticScaling:
    def test_predicted_scale_upper_bounds_jit_scale(self):
        """Paper Fig 4: the predicted trajectory sits above just-in-time
        scaling, so quantized weights never overflow."""
        key = jax.random.PRNGKey(2)
        w = {"w": jax.random.normal(key, (64, 64)) * 0.02}
        opt = init_opt_state(w)
        ocfg = AdamWConfig(weight_decay=0.0)
        qcfg = MOSS_CONFIG
        lr = 1e-3
        st = init_scale_state(w["w"], qcfg)
        for t in range(40):
            g = {"w": jax.random.normal(jax.random.fold_in(key, t),
                                        (64, 64))}
            w, opt = adamw_update(ocfg, w, g, opt,
                                  jnp.asarray(t, jnp.int32),
                                  jnp.float32(lr))
            st = update_scale_state(st, w["w"], qcfg)
            pred = predicted_scale(st, jnp.float32(lr), qcfg)
            jit_scale = float(jnp.abs(w["w"]).max()) / E4M3_MAX
            assert float(pred) >= jit_scale * (1 - 1e-5), t
            # quantized weights stay in range under the predicted scale
            q = jnp.abs(w["w"] / pred).max()
            assert float(q) <= E4M3_MAX

    def test_interval_refresh(self):
        qcfg = QuantConfig(mode="moss", weight_scaling="auto",
                           rescale_interval=5)
        w = jnp.ones((8, 8))
        st = init_scale_state(w, qcfg)
        for t in range(4):
            st = update_scale_state(st, w, qcfg)
            assert int(st.steps_since) == t + 1
        st = update_scale_state(st, w * 3.0, qcfg)   # 5th step: refresh
        assert int(st.steps_since) == 0
        assert abs(float(st.s0) - 3.0 / E4M3_MAX) < 1e-9

    def test_jit_mode_refreshes_every_step(self):
        qcfg = QuantConfig(mode="moss", weight_scaling="jit")
        st = init_scale_state(jnp.ones((4, 4)), qcfg)
        st = update_scale_state(st, jnp.ones((4, 4)) * 7.0, qcfg)
        assert abs(float(st.s0) - 7.0 / E4M3_MAX) < 1e-9
        assert int(st.steps_since) == 0
