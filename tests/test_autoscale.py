"""Automatic scaling tests (paper §3.2, Thm 2, Fig 4, Eq 10).

The property sweep runs under hypothesis when installed and the
deterministic fixed grid from tests/_hypo.py otherwise."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, st

from repro.core.autoscale import (
    init_scale_state,
    predicted_scale,
    update_scale_state,
)
from repro.core.formats import E4M3_MAX, MOSS_CONFIG, QuantConfig
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


class TestTheorem2:
    """|ΔW_t| ≤ η for AdamW (the bound automatic scaling relies on)."""

    def test_update_bounded_by_lr(self):
        key = jax.random.PRNGKey(0)
        w = {"w": jax.random.normal(key, (64, 64))}
        opt = init_opt_state(w)
        cfg = AdamWConfig(weight_decay=0.0)
        lr = 1e-2
        rng = np.random.default_rng(0)
        for t in range(25):
            # adversarial gradients: huge, sparse, sign-flipping
            g = {"w": jnp.asarray(
                rng.normal(size=(64, 64)) * 10.0 ** rng.integers(-3, 4),
                jnp.float32)}
            w_new, opt = adamw_update(cfg, w, g, opt,
                                      jnp.asarray(t, jnp.int32),
                                      jnp.float32(lr))
            delta = jnp.abs(w_new["w"] - w["w"]).max()
            # paper Eq 8: bounded by eta * (1-b1^t)/sqrt(1-b2^t) <= ~1.4eta
            bound = lr * max(1.0, (1 - 0.9 ** (t + 1))
                             / np.sqrt(1 - 0.95 ** (t + 1))) + 1e-7
            assert float(delta) <= bound * 1.01, (t, float(delta), bound)
            w = w_new

    def test_weight_growth_bound(self):
        """max|W_t| <= max|W_0| + eta*t  (the Eq 10 premise)."""
        key = jax.random.PRNGKey(1)
        w = {"w": jax.random.normal(key, (32, 32)) * 0.02}
        w0_max = float(jnp.abs(w["w"]).max())
        opt = init_opt_state(w)
        cfg = AdamWConfig(weight_decay=0.0)
        lr = 5e-3
        for t in range(30):
            g = {"w": jax.random.normal(jax.random.fold_in(key, t),
                                        (32, 32))}
            w, opt = adamw_update(cfg, w, g, opt,
                                  jnp.asarray(t, jnp.int32),
                                  jnp.float32(lr))
            assert float(jnp.abs(w["w"]).max()) <= \
                w0_max + lr * (t + 1) * 1.4 + 1e-6


class TestAutomaticScaling:
    def test_predicted_scale_upper_bounds_jit_scale(self):
        """Paper Fig 4: the predicted trajectory sits above just-in-time
        scaling, so quantized weights never overflow."""
        key = jax.random.PRNGKey(2)
        w = {"w": jax.random.normal(key, (64, 64)) * 0.02}
        opt = init_opt_state(w)
        ocfg = AdamWConfig(weight_decay=0.0)
        qcfg = MOSS_CONFIG
        lr = 1e-3
        st = init_scale_state(w["w"], qcfg)
        for t in range(40):
            g = {"w": jax.random.normal(jax.random.fold_in(key, t),
                                        (64, 64))}
            w, opt = adamw_update(ocfg, w, g, opt,
                                  jnp.asarray(t, jnp.int32),
                                  jnp.float32(lr))
            st = update_scale_state(st, w["w"], qcfg)
            pred = predicted_scale(st, jnp.float32(lr), qcfg)
            jit_scale = float(jnp.abs(w["w"]).max()) / E4M3_MAX
            assert float(pred) >= jit_scale * (1 - 1e-5), t
            # quantized weights stay in range under the predicted scale
            q = jnp.abs(w["w"] / pred).max()
            assert float(q) <= E4M3_MAX

    def test_interval_refresh(self):
        qcfg = QuantConfig(mode="moss", weight_scaling="auto",
                           rescale_interval=5)
        w = jnp.ones((8, 8))
        st = init_scale_state(w, qcfg)
        for t in range(4):
            st = update_scale_state(st, w, qcfg)
            assert int(st.steps_since) == t + 1
        st = update_scale_state(st, w * 3.0, qcfg)   # 5th step: refresh
        assert int(st.steps_since) == 0
        assert abs(float(st.s0) - 3.0 / E4M3_MAX) < 1e-9

    def test_jit_mode_refreshes_every_step(self):
        qcfg = QuantConfig(mode="moss", weight_scaling="jit")
        s = init_scale_state(jnp.ones((4, 4)), qcfg)
        s = update_scale_state(s, jnp.ones((4, 4)) * 7.0, qcfg)
        assert abs(float(s.s0) - 7.0 / E4M3_MAX) < 1e-9
        assert int(s.steps_since) == 0


class TestPredictedScaleProperty:
    """Property sweep over AdamW trajectories: the predicted scale
    (paper Eq. 10) upper-bounds the just-in-time scale at EVERY step —
    across learning rates, refresh intervals, mid-trajectory lr
    changes, and refresh boundaries (the step right after a refresh is
    the tightest point of the bound)."""

    @settings(max_examples=12, deadline=None)
    @given(lr=st.floats(1e-4, 2e-2),
           interval=st.integers(2, 11),
           lr_growth=st.floats(0.25, 2.0))
    def test_predicted_upper_bounds_jit_everywhere(self, lr, interval,
                                                   lr_growth):
        qcfg = QuantConfig(mode="moss", weight_scaling="auto",
                           rescale_interval=int(interval))
        key = jax.random.PRNGKey(7)
        w = {"w": jax.random.normal(key, (48, 48)) * 0.02}
        opt = init_opt_state(w)
        ocfg = AdamWConfig(weight_decay=0.0)
        state = init_scale_state(w["w"], qcfg)
        steps = 3 * int(interval) + 2      # ≥ 3 refresh boundaries
        for t in range(steps):
            # lr schedule with a mid-trajectory change: Thm 2 bounds
            # each step by ITS OWN η, so the prediction must track it
            lr_t = lr if t < steps // 2 else lr * lr_growth
            g = {"w": jax.random.normal(jax.random.fold_in(key, t),
                                        (48, 48))}
            w, opt = adamw_update(ocfg, w, g, opt,
                                  jnp.asarray(t, jnp.int32),
                                  jnp.float32(lr_t))
            state = update_scale_state(state, w["w"], qcfg)
            pred = float(predicted_scale(state, jnp.float32(lr_t),
                                         qcfg))
            jit_scale = float(jnp.abs(w["w"]).max()) / E4M3_MAX
            # bias-corrected AdamW steps can exceed η by ≤ ~1.4× for
            # the first few steps (paper Eq 8) — same slack Thm 2
            # grants; thereafter the bound is strict
            slack = 1.4 if t < 5 else 1.0 + 1e-5
            assert pred * slack >= jit_scale, \
                (t, int(interval), pred, jit_scale)
            # and quantizing against the prediction never overflows
            # beyond that same slack
            q = float(jnp.abs(w["w"] / max(pred, 1e-30)).max())
            assert q <= E4M3_MAX * slack, (t, q)

    @settings(max_examples=8, deadline=None)
    @given(interval=st.integers(2, 9), scale_jump=st.floats(1.0, 8.0))
    def test_refresh_boundary_resets_to_measured_amax(self, interval,
                                                      scale_jump):
        """At a refresh boundary the state re-measures: s0 equals the
        true amax/FP8_MAX even after the weights grew mid-interval,
        and steps_since restarts the Eq. 10 ramp."""
        qcfg = QuantConfig(mode="moss", weight_scaling="auto",
                           rescale_interval=int(interval))
        w = jnp.ones((8, 8))
        state = init_scale_state(w, qcfg)
        for t in range(int(interval) - 1):
            state = update_scale_state(state, w * scale_jump, qcfg)
            assert int(state.steps_since) == t + 1
            # between refreshes the prediction ignores the growth...
            assert abs(float(state.s0) - 1.0 / E4M3_MAX) < 1e-9
        state = update_scale_state(state, w * scale_jump, qcfg)
        # ...and the boundary snaps to the measured value
        assert int(state.steps_since) == 0
        assert abs(float(state.s0) - scale_jump / E4M3_MAX) < 1e-9
