import os
import sys

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see
# 1 device (the 512-device override belongs ONLY to launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
