"""Pipeline-parallelism correctness: GPipe schedule over the pod axis
must reproduce the sequential layer stack exactly."""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.compat.jaxapi import mesh_from_devices
        from repro.distributed.pipeline import pipeline_forward

        n_stages, n_micro, mb, d = 4, 8, 2, 16
        mesh = mesh_from_devices(
            np.asarray(jax.devices()).reshape(4,), ("pod",))
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (n_stages, d, d)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (n_micro, mb, d))

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        y_pipe = pipeline_forward(mesh, stage_fn, W, x,
                                  n_stages=n_stages)
        # sequential reference
        y_ref = x
        for s in range(n_stages):
            y_ref = jnp.tanh(y_ref @ W[s])
        err = float(jnp.abs(y_pipe - y_ref).max())
        print("PIPE_ERR", err)
        assert err < 1e-5, err
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2500:]
    assert "PIPE_ERR" in out.stdout
