"""Optional-hypothesis shim for the property tests.

``hypothesis`` is a test *extra* (see pyproject.toml).  When it is
installed, this module re-exports the real ``given``/``settings``/
``strategies``.  When it is not, a deterministic fixed-sweep fallback
runs each property test over a small parameter grid (first / middle /
last of every strategy, capped product) so tier-1 collects and passes
everywhere without the dependency.
"""

from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # fixed-sweep fallback
    HAVE_HYPOTHESIS = False

    _MAX_CASES = 8

    class _Fixed:
        """A strategy stub carrying a small list of concrete examples."""

        def __init__(self, examples):
            self.examples = list(examples)

    class _Strategies:
        @staticmethod
        def sampled_from(xs):
            xs = list(xs)
            picks = [xs[0], xs[len(xs) // 2], xs[-1]]
            return _Fixed(dict.fromkeys(picks))      # dedup, keep order

        @staticmethod
        def integers(lo, hi):
            return _Fixed(dict.fromkeys([lo, (lo + hi) // 2, hi]))

        @staticmethod
        def floats(lo, hi, **_kw):
            return _Fixed(dict.fromkeys([lo, (lo + hi) / 2.0, hi]))

    st = _Strategies()

    def settings(*_a, **_kw):                        # noqa: D401
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)
        grids = [strategies[n].examples for n in names]
        combos = list(itertools.product(*grids))
        if len(combos) > _MAX_CASES:                 # deterministic cap
            step = len(combos) / _MAX_CASES
            combos = [combos[int(i * step)] for i in range(_MAX_CASES)]

        def deco(fn):
            def run(*args, **kw):                    # args = (self,) or ()
                for combo in combos:
                    fn(*args, **kw, **dict(zip(names, combo)))
            # NOT functools.wraps: pytest must see run's own (*args)
            # signature, or it would treat fn's params as fixtures
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            return run
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
