"""Observability layer (docs/observability.md): metrics registry +
exporters, Chrome-trace spans, and fp8 quant-health telemetry.

The load-bearing acceptance test is `test_health_off_is_free`: with
REPRO_QUANT_HEALTH off and tracing unset, the decode/verify jaxprs
must be BYTE-IDENTICAL to an obs-free build (building and tracing a
health-enabled step in between must not leak into them), and the
delayed-scale decode graph keeps ZERO quantization reductions.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.actscale import ActScale, calibrate_act_scales
from repro.core.formats import QuantConfig, fp8_max
from repro.core.introspect import count_quant_reductions
from repro.core.quant import quant_excursions
from repro.models.layers import init_tree
from repro.models.transformer import init_caches, model_defs
from repro.obs.metrics import (
    DRIFT_BUCKETS,
    LATENCY_BUCKETS_S,
    RATE_BUCKETS,
    Registry,
    get_registry,
)
from repro.obs.quant_health import (
    DRIFT_THRESHOLD,
    HealthAggregator,
    TaggedScale,
    site_stats,
)
from repro.obs.trace import Tracer
from repro.serving.scheduler import Request, Scheduler
from repro.train.steps import (
    make_decode_step,
    make_verify_step,
    prequantize_params,
)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_inc_and_set_total():
    reg = Registry()
    c = reg.counter("events_total", help="h")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    # set_total adopts an external running total, max-wise: calling
    # stats() repeatedly must not double count or move backwards
    c2 = reg.counter("engine_preemptions_total")
    c2.set_total(4)
    c2.set_total(4)
    c2.set_total(2)               # stale read never decreases
    assert c2.value() == 4.0


def test_gauge_and_labels():
    reg = Registry()
    g = reg.gauge("pages_in_use")
    g.set(7, labels={"pool": "kv"})
    g.set(3, labels={"pool": "host"})
    assert g.value(labels={"pool": "kv"}) == 7.0
    assert g.value(labels={"pool": "host"}) == 3.0


def test_histogram_bucket_counts_exact():
    reg = Registry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.1, 0.5, 2.0, 100.0):
        h.observe(v)
    snap = reg.snapshot()["lat"]["series"][""]
    # per-bucket: <=0.1 gets 0.05 and 0.1; <=1.0 gets 0.5; <=10 gets
    # 2.0; overflow gets 100
    assert snap["counts"] == [2, 1, 1, 1]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(102.65)
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(1.0, 1.0, 2.0))


def test_registry_kind_mismatch_is_error():
    reg = Registry()
    reg.counter("x")
    assert reg.counter("x") is reg.counter("x")   # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_snapshot_and_json_never_nan():
    reg = Registry()
    reg.gauge("g").set(float("nan"))
    reg.gauge("g2").set(float("inf"))
    snap = reg.snapshot()
    assert snap["g"]["series"][""] is None
    assert snap["g2"]["series"][""] is None
    # json.dumps(allow_nan=False) would raise on NaN/Inf leakage
    json.loads(reg.to_json())


def test_prometheus_text_format():
    reg = Registry()
    reg.counter("req_total", help="requests").inc(3)
    h = reg.histogram("ttft", buckets=(0.1, 1.0),
                      help="time to first token")
    h.observe(0.05, labels={"site": "a"})
    h.observe(5.0, labels={"site": "a"})
    text = reg.to_prometheus()
    assert "# HELP req_total requests" in text
    assert "# TYPE req_total counter" in text
    assert "req_total 3" in text
    assert "# TYPE ttft histogram" in text
    # cumulative buckets + the +Inf catch-all, sum and count
    assert 'ttft_bucket{site="a",le="0.1"} 1' in text
    assert 'ttft_bucket{site="a",le="1"} 1' in text
    assert 'ttft_bucket{site="a",le="+Inf"} 2' in text
    assert 'ttft_sum{site="a"} 5.05' in text
    assert 'ttft_count{site="a"} 2' in text


# ---------------------------------------------------------------------------
# Chrome-trace spans
# ---------------------------------------------------------------------------


def test_trace_span_schema_and_save(tmp_path):
    t = Tracer()
    t.enable(path=str(tmp_path / "trace.json"))
    with t.span("engine.step", rows=3):
        with t.span("decode"):
            pass
    t.instant("preempt", rid=7)
    evs = t.events()
    assert [e["name"] for e in evs] == ["decode", "engine.step",
                                       "preempt"]
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        assert isinstance(e["ts"], int)
    x0, x1, inst = evs
    assert x0["ph"] == "X" and x1["ph"] == "X" and inst["ph"] == "i"
    assert x0["dur"] >= 0 and x1["dur"] >= x0["dur"]  # nesting
    assert x1["args"] == {"rows": 3} and inst["args"] == {"rid": 7}
    # the saved file is a Chrome-trace JSON array Perfetto accepts
    path = t.save()
    loaded = json.load(open(path))
    assert loaded == evs


def test_trace_ring_buffer_bounds_memory():
    t = Tracer(capacity=4)
    t.enable()
    for i in range(10):
        with t.span(f"s{i}"):
            pass
    assert len(t) == 4
    assert [e["name"] for e in t.events()] == ["s6", "s7", "s8", "s9"]


def test_trace_disabled_is_shared_noop():
    t = Tracer()
    a, b = t.span("x"), t.span("y", k=1)
    assert a is b                 # one shared null context manager
    with a:
        pass
    assert len(t) == 0
    t.instant("z")
    assert len(t) == 0


# ---------------------------------------------------------------------------
# Quant health: exact stats on crafted tensors
# ---------------------------------------------------------------------------

_PT = QuantConfig(mode="per_tensor")


def test_quant_excursions_exact():
    fmax = fp8_max("e4m3")        # 448
    scale = jnp.float32(1.0 / fmax)   # representable max = 1.0
    x = jnp.abs(jnp.asarray(
        [2.0, 1.5, 1.0, 0.5, 1e-6, 0.0, 0.25, 0.125], jnp.float32))
    sat, under, nonzero = quant_excursions(x, scale, "e4m3")
    # 2.0 and 1.5 clip; 1.0 is exactly representable; 1e-6/scale =
    # 4.48e-4 < e4m3's rounding floor (2^-10) so it quantizes to 0;
    # the true 0.0 is not an underflow (it was never information)
    assert float(sat) == 2.0
    assert float(under) == 1.0
    assert float(nonzero) == 7.0


def test_site_stats_exact_per_tensor():
    fmax = fp8_max("e4m3")
    a = ActScale(s=jnp.float32(1.0 / fmax), sub=jnp.zeros((), jnp.int8))
    x = jnp.asarray([[2.0, -1.5, 1.0, 0.5, 1e-6, 0.0, -0.25, 0.125]],
                    jnp.float32)
    st = {k: float(v) for k, v in site_stats(x, a, _PT).items()}
    assert st["n"] == 8.0
    assert st["sat"] == 2.0
    assert st["underflow"] == 1.0
    assert st["nonzero"] == 7.0
    assert st["amax"] == 2.0
    # drift = amax / (s * fmax) = 2.0 / 1.0
    assert st["drift"] == pytest.approx(2.0)


def test_site_stats_healthy_drift_below_threshold():
    # a calibration-style scale (margin 1.25 over the live amax) puts
    # drift at exactly 1/margin — comfortably under the threshold
    fmax = fp8_max("e4m3")
    margin = 1.25
    a = ActScale(s=jnp.float32(margin * 2.0 / fmax),
                 sub=jnp.zeros((), jnp.int8))
    x = jnp.full((4, 8), 2.0, jnp.float32)
    st = site_stats(x, a, _PT)
    assert float(st["drift"]) == pytest.approx(1 / margin)
    assert float(st["drift"]) < DRIFT_THRESHOLD
    assert float(st["sat"]) == 0.0


def test_health_aggregator_rates_and_refresh_flag():
    reg = Registry()
    agg = HealthAggregator(registry=reg)
    # stacked-(layers,) stats as the scan emits them: 2 layers
    healthy = {"blocks/ffn/w1": {
        "n": np.asarray([8.0, 8.0]), "sat": np.asarray([0.0, 0.0]),
        "underflow": np.asarray([0.0, 0.0]),
        "nonzero": np.asarray([8.0, 8.0]),
        "amax": np.asarray([1.0, 1.0]),
        "drift": np.asarray([0.8, 0.7])}}
    agg.ingest(healthy)
    assert not agg.refresh_recommended
    bad = {"blocks/ffn/w1": {
        "n": np.asarray([8.0, 8.0]), "sat": np.asarray([2.0, 0.0]),
        "underflow": np.asarray([1.0, 0.0]),
        "nonzero": np.asarray([7.0, 8.0]),
        "amax": np.asarray([2.0, 1.0]),
        "drift": np.asarray([2.0, 0.7])}}
    agg.ingest(bad)
    assert agg.refresh_recommended
    assert reg.gauge("quant_health_refresh_recommended").value() == 1.0
    rep = agg.report()["blocks/ffn/w1"]
    assert rep["saturation_rate"] == pytest.approx(2 / 32)
    assert rep["underflow_rate"] == pytest.approx(1 / 31)
    assert rep["drift_max"] == pytest.approx(2.0)
    assert rep["steps"] == 2
    # histograms got one observation per ingest per site
    snap = reg.snapshot()
    series = snap["quant_health_drift_ratio"]["series"]
    assert series['{site="blocks/ffn/w1"}']["count"] == 2
    agg.ingest({})                # empty step tree is a no-op
    assert agg.report()["blocks/ffn/w1"]["steps"] == 2


# ---------------------------------------------------------------------------
# Quant health end-to-end: the step functions
# ---------------------------------------------------------------------------


def _serving_build(cfg):
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    pq = prequantize_params(cfg, params)
    act = calibrate_act_scales(cfg, pq.qweights, pq.scales)
    return pq.qweights, pq.scales, act


def test_health_step_reports_sites_and_stale_scale_trips_flag():
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    qw, scales, act = _serving_build(cfg)
    caches = init_caches(cfg, 2, 16, per_slot=True)
    feed = jnp.ones((2, 1), jnp.int32)
    step = jax.jit(make_decode_step(cfg, scales=scales, act_scales=act,
                                    quant_health=True))
    logits, caches2, tree = step(qw, caches, feed)
    assert tree, "health-enabled decode returned no site stats"
    assert all("/" in tag for tag in tree)      # path_tag site keys
    stacked = [t for t, st in tree.items() if st["drift"].ndim == 1]
    assert stacked, "no scan-stacked (layers,) site stats"
    # numerically identical logits to the health-off step
    step_off = jax.jit(make_decode_step(cfg, scales=scales,
                                        act_scales=act))
    logits_off, _ = step_off(qw, caches, feed)
    np.testing.assert_array_equal(np.asarray(logits),
                                  np.asarray(logits_off))
    reg = Registry()
    agg = HealthAggregator(registry=reg)
    agg.ingest(tree)
    calibrated_drift = max(s["drift_max"]
                           for s in agg.report().values())
    # a deliberately STALE ActScale — live amax far beyond calibrated
    # × margin — must drive drift over the threshold and recommend a
    # refresh (the Engine.refresh_act_scales runbook)
    stale = {tag: ActScale(s=jax.tree.map(lambda v: v * 0.25, a.s),
                           sub=a.sub) for tag, a in act.items()}
    step_stale = jax.jit(make_decode_step(cfg, scales=scales,
                                          act_scales=stale,
                                          quant_health=True))
    _, _, tree_stale = step_stale(qw, caches, feed)
    agg2 = HealthAggregator(registry=Registry())
    agg2.ingest(tree_stale)
    assert agg2.refresh_recommended
    stale_drift = max(s["drift_max"] for s in agg2.report().values())
    assert stale_drift == pytest.approx(4 * calibrated_drift, rel=1e-3)


def test_tagged_scale_is_pytree_with_static_tag():
    ts = TaggedScale("blocks/attn/wq",
                     ActScale(s=jnp.ones((3,)), sub=jnp.zeros((3,),
                                                              jnp.int8)))
    leaves, treedef = jax.tree_util.tree_flatten(ts)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.tag == "blocks/attn/wq"
    # scan-style slicing keeps the tag and slices the arrays
    sliced = jax.tree.map(lambda x: x[0], ts)
    assert sliced.tag == ts.tag and sliced.scale.s.shape == ()


# ---------------------------------------------------------------------------
# THE acceptance contract: telemetry off is free
# ---------------------------------------------------------------------------


def test_health_off_is_free(monkeypatch):
    """With REPRO_QUANT_HEALTH=0 and REPRO_TRACE unset the decode and
    verify jaxprs are byte-identical to an obs-free build — tracing a
    health-enabled step in between must not leak (module-collector
    state, TaggedScale wrapping) into later off builds — and the
    delayed-scale decode graph keeps ZERO quantization reductions."""
    monkeypatch.delenv("REPRO_QUANT_HEALTH", raising=False)
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        kv_cache_dtype="bf16")    # fp8 cache keeps its 2 storage amaxes
    qw, scales, act = _serving_build(cfg)
    caches = init_caches(cfg, 2, 16, per_slot=True)
    feed1 = jnp.zeros((2, 1), jnp.int32)
    feedk = jnp.zeros((2, 4), jnp.int32)

    def jaxprs():
        dec = jax.make_jaxpr(make_decode_step(
            cfg, scales=scales, act_scales=act))(qw, caches, feed1)
        ver = jax.make_jaxpr(make_verify_step(
            cfg, scales=scales, act_scales=act))(qw, caches, feedk)
        return dec, ver

    dec0, ver0 = jaxprs()
    assert count_quant_reductions(dec0) == 0
    assert count_quant_reductions(ver0) == 0
    # build AND trace health-enabled steps (the leak hazard)
    jax.make_jaxpr(make_decode_step(cfg, scales=scales, act_scales=act,
                                    quant_health=True))(qw, caches,
                                                        feed1)
    jax.make_jaxpr(make_verify_step(cfg, scales=scales, act_scales=act,
                                    quant_health=True))(qw, caches,
                                                        feedk)
    dec1, ver1 = jaxprs()
    assert str(dec0) == str(dec1), "decode jaxpr changed after a " \
        "health-enabled build — telemetry off is not free"
    assert str(ver0) == str(ver1)


def test_health_on_adds_no_quant_reductions():
    """The health stats are element-wise compares + small max
    reductions that never feed an fp8 cast: count_quant_reductions
    stays 0 even with telemetry ON (bf16 cache isolates the KV
    storage amaxes away)."""
    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        kv_cache_dtype="bf16")
    qw, scales, act = _serving_build(cfg)
    caches = init_caches(cfg, 2, 16, per_slot=True)
    jx = jax.make_jaxpr(make_decode_step(
        cfg, scales=scales, act_scales=act, quant_health=True))(
        qw, caches, jnp.zeros((2, 1), jnp.int32))
    assert count_quant_reductions(jx) == 0


# ---------------------------------------------------------------------------
# Scheduler summary: NaN-free JSON + registry routing
# ---------------------------------------------------------------------------


def test_scheduler_summary_empty_is_valid_json():
    s = Scheduler().summary()
    for key in ("tok_per_s", "mean_ttft_s", "mean_tpot_s", "p50_ttft_s",
                "p99_tpot_s", "spec_accept_rate"):
        assert s[key] is None, f"{key} should be None with no data"
    text = json.dumps(s, allow_nan=False)   # raises on NaN leakage
    assert "NaN" not in text


def test_scheduler_publishes_latency_histograms():
    get_registry().reset()
    state = {"t": 0.0}
    sched = Scheduler(clock=lambda: state["t"])
    req = Request(rid=0, prompt=np.zeros(4, np.int32), max_new=2)
    sched.submit([req])
    sched.pop()
    state["t"] = 0.3
    sched.on_token(req, 1)
    state["t"] = 0.35
    assert sched.on_token(req, 2) and req.done
    snap = get_registry().snapshot()
    assert snap["sched_ttft_seconds"]["series"][""]["count"] == 1
    assert snap["sched_ttft_seconds"]["series"][""]["sum"] == \
        pytest.approx(0.3)
    assert snap["sched_tpot_seconds"]["series"][""]["count"] == 1
    s = sched.summary()
    assert snap is not None and s["requests"] == 1
    assert get_registry().counter(
        "sched_tokens_generated_total").value() == 2.0
