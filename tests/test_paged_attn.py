"""Floating-page decode-attention contract (docs/paged-attention.md):

- paged-vs-contiguous bitwise parity: scattering a contiguous cache's
  pages into arbitrary physical rows of a global pool and decoding
  through the block table reproduces the contiguous decode EXACTLY
  (fp8 AND bf16 cache, ref AND interpret backends);
- slots may alias the SAME physical pages (the prefix-sharing read
  path) without perturbing each other;
- mixed per-slot depths through one paged launch match per-row calls;
- the paged decode keeps the fused-kernel jaxpr contract: zero
  pool-sized dequant upcasts / dots on the kernel path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.ref import decode_attn_ref, gather_pages

B, KV, G, DH, T, NP = 3, 2, 4, 32, 16, 4
C = NP * T
POOL = 16          # > B*NP so the scatter can scramble freely


def _quant(x):
    from repro.core.formats import E4M3_MAX, TINY

    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(amax, TINY) / E4M3_MAX
    return (x.astype(jnp.float32) / s[..., None]).astype(
        jnp.float8_e4m3fn), s


def _contiguous(seed, kv_dtype):
    """A contiguous (B, KV, C, Dh) cache + queries."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, KV, G, DH)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KV, C, DH)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, KV, C, DH)), jnp.bfloat16)
    if kv_dtype == "fp8":
        k, ks = _quant(k)
        v, vs = _quant(v)
        return q, k, v, ks, vs
    return q, k, v, None, None


def _scatter(k, v, ks, vs, seed=7):
    """Scramble the contiguous cache's pages into a (P, KV, T, ·)
    pool; rows not referenced by the block table hold garbage."""
    rng = np.random.default_rng(seed)
    bt = rng.permutation(POOL)[:B * NP].reshape(B, NP).astype(np.int32)

    def pool_of(src, scale):
        shape = ((POOL, KV, T) if scale else (POOL, KV, T, DH))
        buf = jnp.asarray(rng.standard_normal(shape),
                          jnp.float32).astype(src.dtype)
        for b in range(B):
            for j in range(NP):
                buf = buf.at[bt[b, j]].set(src[b, :, j * T:(j + 1) * T])
        return buf

    pk, pv = pool_of(k, False), pool_of(v, False)
    pks = pool_of(ks, True) if ks is not None else None
    pvs = pool_of(vs, True) if vs is not None else None
    return pk, pv, pks, pvs, jnp.asarray(bt)


NV = jnp.asarray([5, 37, C], jnp.int32)     # mixed depths incl. full


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_paged_vs_contiguous_bitwise(kv_dtype, backend):
    q, k, v, ks, vs = _contiguous(0, kv_dtype)
    pk, pv, pks, pvs, bt = _scatter(k, v, ks, vs)
    base = decode_attn_ref(q, k, v, ks, vs, NV, sm_scale=DH ** -0.5)
    out = dispatch.decode_attention_paged(q, pk, pv, pks, pvs, NV, bt,
                                          backend=backend)
    assert jnp.array_equal(base, out), \
        (kv_dtype, backend, float(jnp.abs(base - out).max()))
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("kv_dtype", ["fp8", "bf16"])
def test_ref_vs_interpret_bitwise(kv_dtype):
    q, k, v, ks, vs = _contiguous(1, kv_dtype)
    pk, pv, pks, pvs, bt = _scatter(k, v, ks, vs)
    outs = {b: dispatch.decode_attention_paged(
        q, pk, pv, pks, pvs, NV, bt, backend=b)
        for b in ("ref", "interpret")}
    assert jnp.array_equal(outs["ref"], outs["interpret"])


def test_shared_pages_alias_without_perturbation():
    """Two slots whose block tables point at the SAME physical pages
    (prefix sharing) read identical bytes: slot outputs equal the
    solo decode of the shared content, bitwise."""
    q, k, v, ks, vs = _contiguous(2, "fp8")
    pk, pv, pks, pvs, bt = _scatter(k, v, ks, vs)
    # slot 1 aliases slot 0's first two pages, then diverges into its
    # own pages — the CoW layout after a 2-page prefix hit
    bt = bt.at[1, :2].set(bt[0, :2])
    nv = jnp.asarray([2 * T, 2 * T, C], jnp.int32)
    out = dispatch.decode_attention_paged(q, pk, pv, pks, pvs, nv, bt,
                                          backend="interpret")
    # slot 1 must see slot 0's K/V: rebuild its contiguous view from
    # the aliased tables and compare against the oracle per slot
    kg, vg = gather_pages(pk, bt), gather_pages(pv, bt)
    ksg, vsg = gather_pages(pks, bt), gather_pages(pvs, bt)
    base = decode_attn_ref(q, kg, vg, ksg, vsg, nv, sm_scale=DH ** -0.5)
    assert jnp.array_equal(base, out)
    assert jnp.array_equal(kg[0, :, :2 * T], kg[1, :, :2 * T])


def test_mixed_depth_rows_match_per_row_calls():
    """One paged launch over rows at different depths is bitwise a
    stack of single-row launches (batch-composition independence)."""
    q, k, v, ks, vs = _contiguous(3, "fp8")
    pk, pv, pks, pvs, bt = _scatter(k, v, ks, vs)
    batched = dispatch.decode_attention_paged(q, pk, pv, pks, pvs, NV,
                                              bt, backend="interpret")
    for b in range(B):
        solo = dispatch.decode_attention_paged(
            q[b:b + 1], pk, pv, pks, pvs, NV[b:b + 1], bt[b:b + 1],
            backend="interpret")
        assert jnp.array_equal(batched[b:b + 1], solo), b


def test_paged_jaxpr_zero_pool_sized_upcasts_and_dots():
    """The kernel path gathers pages inside the Pallas index maps:
    the jaxpr outside the kernel launch holds ZERO pool-sized fp8
    dequant upcasts and ZERO pool-sized dots (the ref path's gather +
    einsum shows both — the counters see what the kernel removed)."""
    from repro.core.introspect import (
        count_dot_general_over,
        count_fp8_dequant_upcasts,
        count_primitive,
    )

    q, k, v, ks, vs = _contiguous(4, "fp8")
    pk, pv, pks, pvs, bt = _scatter(k, v, ks, vs)
    # cache-sized: the gathered per-slot view AND the pool itself
    sizes = {B * KV * C * DH, POOL * KV * T * DH}

    def run(backend):
        return jax.make_jaxpr(
            lambda *a: dispatch.decode_attention_paged(
                *a, backend=backend))(q, pk, pv, pks, pvs, NV, bt)

    jx_ref, jx_k = run("ref"), run("interpret")
    assert count_fp8_dequant_upcasts(jx_ref, sizes) > 0
    assert count_dot_general_over(jx_ref, sizes) > 0
    assert count_fp8_dequant_upcasts(jx_k, sizes) == 0
    assert count_dot_general_over(jx_k, sizes) == 0
    assert count_primitive(jx_k, "pallas_call") == 1
