"""Unit tests for the jaxpr introspection layer (core/introspect.py).

The serving acceptance contracts (zero weight quantizes, zero cache
dequants, zero quantization reductions in the delayed decode graph)
are only as strong as the counters backing them — so the counters get
their own direct tests on hand-built jaxprs, positive AND negative:
a counter that can't tell a softmax max from a quantizer amax would
pass the acceptance suite for the wrong reason.

The decode-graph acceptance assertions themselves (the reduction-free
delayed path per recipe) live at the bottom — this file runs in the CI
tier-1 fast lane.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.core.formats import (
    MOSS_CONFIG,
    PER_GROUP_CONFIG,
    PER_TENSOR_CONFIG,
)
from repro.core.introspect import (
    count_dot_general_over,
    count_fp8_casts,
    count_fp8_dequant_upcasts,
    count_primitive,
    count_quant_reductions,
    count_reduce_max_over,
    kv_cache_slice_sizes,
)

E4M3 = jnp.float8_e4m3fn


def _quantize(x):
    """The canonical just-in-time per-tensor quantizer shape:
    reduce_max → scale arithmetic → fp8 cast."""
    s = jnp.max(jnp.abs(x)) / 448.0
    return (x / s).astype(E4M3)


# ---------------------------------------------------------------------------
# Size-keyed counters
# ---------------------------------------------------------------------------


class TestSizeKeyedCounters:
    def test_count_reduce_max_over_selects_by_operand_size(self):
        def f(w, x):
            return jnp.max(jnp.abs(w)) + jnp.max(jnp.abs(x))

        jx = jax.make_jaxpr(f)(jnp.ones((8, 16)), jnp.ones((4,)))
        assert count_reduce_max_over(jx, {128}) == 1    # the (8,16) one
        assert count_reduce_max_over(jx, {4}) == 1
        assert count_reduce_max_over(jx, {128, 4}) == 2
        assert count_reduce_max_over(jx, {999}) == 0

    def test_count_fp8_casts_all_and_sized(self):
        def f(w, x):
            return _quantize(w), _quantize(x)

        jx = jax.make_jaxpr(f)(jnp.ones((8, 16)), jnp.ones((4,)))
        assert count_fp8_casts(jx) == 2
        assert count_fp8_casts(jx, {128}) == 1
        assert count_fp8_casts(jx, {7}) == 0
        # a bf16 cast is not an fp8 cast
        jx2 = jax.make_jaxpr(lambda x: x.astype(jnp.bfloat16))(
            jnp.ones((4,)))
        assert count_fp8_casts(jx2) == 0

    def test_count_fp8_dequant_upcasts(self):
        q = jnp.ones((8, 16), E4M3)

        jx = jax.make_jaxpr(lambda q: q.astype(jnp.float32) * 2.0)(q)
        assert count_fp8_dequant_upcasts(jx, {128}) == 1
        assert count_fp8_dequant_upcasts(jx, {64}) == 0
        # fp8→fp8 is a recast, not a dequant; bf16→f32 is not fp8
        jx2 = jax.make_jaxpr(lambda q: q.astype(jnp.float8_e5m2))(q)
        assert count_fp8_dequant_upcasts(jx2, {128}) == 0
        jx3 = jax.make_jaxpr(lambda x: x.astype(jnp.float32))(
            jnp.ones((8, 16), jnp.bfloat16))
        assert count_fp8_dequant_upcasts(jx3, {128}) == 0

    def test_count_dot_general_over(self):
        def f(a, b, c):
            return (a @ b) @ c

        jx = jax.make_jaxpr(f)(jnp.ones((2, 64)), jnp.ones((64, 32)),
                               jnp.ones((32, 8)))
        assert count_dot_general_over(jx, {64 * 32}) == 1
        assert count_dot_general_over(jx, {32 * 8}) == 1
        assert count_dot_general_over(jx, {5}) == 0

    def test_kv_cache_slice_sizes_matches_layout(self):
        from repro.models.attention import cache_len

        cfg = get_config("phi3-mini-3.8b", smoke=True)
        batch, max_len = 2, 32
        c = cache_len(cfg, max_len)
        assert kv_cache_slice_sizes(cfg, batch, max_len) == \
            {batch * cfg.n_kv * c * cfg.head_dim}


# ---------------------------------------------------------------------------
# count_quant_reductions — positives
# ---------------------------------------------------------------------------


class TestQuantReductionPositives:
    def test_per_tensor_quantizer_counts_one(self):
        jx = jax.make_jaxpr(_quantize)(jnp.ones((8, 16)))
        assert count_quant_reductions(jx) == 1

    def test_two_level_quantizer_counts_both_reductions(self):
        def moss_like(x):
            g = jnp.max(jnp.abs(x).reshape(-1, 4), axis=-1)   # micro amax
            s1 = jnp.max(g)                                   # global amax
            sub = jnp.exp2(jnp.ceil(jnp.log2(g / s1)))
            scale = (s1 / 448.0) * sub
            return (x.reshape(-1, 4) / scale[:, None]).astype(E4M3)

        jx = jax.make_jaxpr(moss_like)(jnp.ones((32,)))
        assert count_quant_reductions(jx) == 2

    def test_cast_inside_pjit_is_reached(self):
        """The amax chain must survive a call boundary: the reduction
        in the outer jaxpr, the fp8 cast inside a jitted callee (the
        shape real decode graphs have)."""

        @jax.jit
        def cast(x, s):
            return (x / s).astype(E4M3)

        def f(x):
            s = jnp.max(jnp.abs(x)) / 448.0
            return cast(x, s)

        jx = jax.make_jaxpr(f)(jnp.ones((8,)))
        assert count_quant_reductions(jx) == 1

    def test_quantizer_inside_scan_counts_once(self):
        """Structural counting: one reduction in a scan body is one,
        not one per trip."""

        def body(c, x):
            return c, _quantize(x)

        def f(xs):
            return jax.lax.scan(body, 0.0, xs)[1]

        jx = jax.make_jaxpr(f)(jnp.ones((5, 8)))
        assert count_quant_reductions(jx) == 1

    def test_real_quantizers_count(self):
        from repro.core.quant import quant_mx, quant_per_group, quant_per_tensor

        x = jnp.ones((4, 128))
        assert count_quant_reductions(
            jax.make_jaxpr(lambda x: quant_per_tensor(x).q)(x)) == 1
        assert count_quant_reductions(
            jax.make_jaxpr(lambda x: quant_per_group(x).q)(x)) == 1
        # MOSS two-level: micro-group amax + global amax
        assert count_quant_reductions(
            jax.make_jaxpr(lambda x: quant_mx(x).q)(x)) == 2

    def test_delayed_quantizers_count_zero(self):
        """The delayed variants consume externally supplied scales —
        by construction no reduction feeds their casts."""
        from repro.core.quant import quant_mx_delayed, quant_per_group

        x = jnp.ones((4, 128))
        jx = jax.make_jaxpr(
            lambda x: quant_per_group(x, scale=jnp.ones((4, 1))).q)(x)
        assert count_quant_reductions(jx) == 0
        jx = jax.make_jaxpr(
            lambda x: quant_mx_delayed(x, 1.0, jnp.zeros((4, 4),
                                                         jnp.int8)).q)(x)
        assert count_quant_reductions(jx) == 0
        assert count_fp8_casts(jx) == 1        # still quantizes, scale-free


# ---------------------------------------------------------------------------
# count_quant_reductions — negative controls
# ---------------------------------------------------------------------------


class TestQuantReductionNegatives:
    def test_softmax_max_is_not_a_quant_reduction(self):
        jx = jax.make_jaxpr(jax.nn.softmax)(jnp.ones((4, 16)))
        assert count_primitive(jx, "reduce_max") >= 1
        assert count_quant_reductions(jx) == 0

    def test_softmax_feeding_a_fixed_scale_quantize_stays_zero(self):
        """Attention-like shape: softmax(x) later cast to fp8 with a
        FIXED scale.  The softmax's reduce_max must not be credited
        with the downstream cast — its chain dies at the exp."""

        def f(x):
            p = jax.nn.softmax(x, axis=-1)
            return (p / 0.003).astype(E4M3)

        jx = jax.make_jaxpr(f)(jnp.ones((4, 16)))
        assert count_fp8_casts(jx) == 1
        assert count_quant_reductions(jx) == 0

    def test_masking_max_is_not_a_quant_reduction(self):
        """A reduce_max used for masking/clipping logic with no fp8
        cast downstream."""

        def f(x):
            bound = jnp.max(jnp.abs(x))
            return jnp.where(jnp.abs(x) > 0.5 * bound, 0.0, x)

        jx = jax.make_jaxpr(f)(jnp.ones((8,)))
        assert count_primitive(jx, "reduce_max") == 1
        assert count_quant_reductions(jx) == 0

    def test_chain_dies_at_dot_general(self):
        """An amax that feeds a GEMM whose *output* is quantized with a
        fixed scale: the reduction's influence routes through the dot,
        so it is not a scale computation."""

        def f(x, w):
            y = (x / jnp.max(jnp.abs(x))) @ w
            return (y / 0.01).astype(E4M3)

        jx = jax.make_jaxpr(f)(jnp.ones((2, 8)), jnp.ones((8, 4)))
        assert count_fp8_casts(jx) == 1
        assert count_quant_reductions(jx) == 0


# ---------------------------------------------------------------------------
# The acceptance contract: reduction-free delayed decode (CI fast lane)
# ---------------------------------------------------------------------------

QUANT_MODES = {"per_tensor": PER_TENSOR_CONFIG,
               "per_group": PER_GROUP_CONFIG,
               "moss": MOSS_CONFIG}


def _delayed_decode_jaxpr(mode, arch="phi3-mini-3.8b", delayed=True):
    from repro.core.actscale import calibrate_act_scales
    from repro.models.layers import init_tree
    from repro.models.transformer import init_caches, model_defs
    from repro.train.steps import make_decode_step, prequantize_params

    cfg = get_config(arch, smoke=True).replace(quant=QUANT_MODES[mode],
                                               kv_cache_dtype="bf16")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    pq = prequantize_params(cfg, params)
    act = (calibrate_act_scales(cfg, pq.qweights, pq.scales)
           if delayed else None)
    caches = init_caches(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = make_decode_step(cfg, scales=pq.scales, act_scales=act)
    return jax.make_jaxpr(step)(pq.qweights, caches, tok), cfg


@pytest.mark.parametrize("mode", sorted(QUANT_MODES))
def test_delayed_decode_graph_is_reduction_free(mode):
    """THE acceptance assertion: with delayed activation scales (and a
    bf16 KV cache — the fp8 cache's storage-format write reductions
    are the one documented exception, see below) the decode jaxpr
    contains ZERO quantization reductions, while the just-in-time
    graph contains one (moss: two) per quantized GEMM site."""
    jx_delayed, _ = _delayed_decode_jaxpr(mode)
    jx_jit, _ = _delayed_decode_jaxpr(mode, delayed=False)
    n_jit = count_quant_reductions(jx_jit)
    per_site = 2 if mode == "moss" else 1
    assert n_jit == 8 * per_site, n_jit          # 8 sites on this arch
    assert count_quant_reductions(jx_delayed) == 0


def test_fp8_kv_cache_keeps_only_storage_reductions():
    """Under the fp8 KV cache the delayed decode graph keeps EXACTLY
    the 2 per-layer-stack cache-write amaxes (K and V storage-format
    scales, docs/serving.md) — nothing else."""
    from repro.core.actscale import calibrate_act_scales
    from repro.models.layers import init_tree
    from repro.models.transformer import init_caches, model_defs
    from repro.train.steps import make_decode_step, prequantize_params

    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        quant=MOSS_CONFIG, kv_cache_dtype="fp8")
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    pq = prequantize_params(cfg, params)
    act = calibrate_act_scales(cfg, pq.qweights, pq.scales)
    caches = init_caches(cfg, 2, 16)
    jx = jax.make_jaxpr(make_decode_step(cfg, scales=pq.scales,
                                         act_scales=act))(
        pq.qweights, caches, jnp.zeros((2, 1), jnp.int32))
    assert count_quant_reductions(jx) == 2


def test_tied_head_decode_has_no_vocab_sized_fp8_cast():
    """recurrentgemma-2b (the tied-embedding arch): the prequant
    transposed head removes the per-step re-quantization of
    embeddingᵀ — no vocab-sized fp8 cast survives in the decode
    graph, with or without delayed activation scales (the activation
    feeding the head is d_model-sized, never vocab-sized)."""
    jx, cfg = _delayed_decode_jaxpr("moss", arch="recurrentgemma-2b")
    head_sizes = {cfg.d_model * cfg.vocab}
    assert count_fp8_casts(jx, head_sizes) == 0
    jx_jit, _ = _delayed_decode_jaxpr("moss", arch="recurrentgemma-2b",
                                      delayed=False)
    assert count_fp8_casts(jx_jit, head_sizes) == 0
