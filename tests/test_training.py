"""Training-system integration tests: MOSS-vs-BF16 convergence parity
(paper Fig 5), checkpoint resume, fp8 gradient compression, recurrence
oracles."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.registry import get_config
from repro.core.formats import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.train import quant_from_name, train
from repro.train.steps import TrainHParams, init_train_state, make_train_step


def _run(arch, quant, steps=60, seed=0, lr=1e-3):
    cfg = get_config(arch, smoke=True).replace(
        quant=quant_from_name(quant))
    hp = TrainHParams(peak_lr=lr, warmup_steps=5, total_steps=steps)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8, seed=seed))
    state = init_train_state(cfg, hp, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, hp))
    losses = []
    for t in range(steps):
        state, m = step(state, data.batch_for_step(t))
        losses.append(float(m["loss"]))
    return np.asarray(losses)


class TestConvergenceParity:
    """Paper Fig 5 analogue: MOSS loss curve tracks BF16 closely."""

    def test_moss_matches_bf16(self):
        bf16 = _run("olmo-7b", "bf16")
        moss = _run("olmo-7b", "moss")
        assert moss[-1] < moss[0] * 0.95          # actually learning
        # late-phase average loss within 3% of the bf16 baseline
        gap = abs(moss[-10:].mean() - bf16[-10:].mean()) \
            / bf16[-10:].mean()
        assert gap < 0.03, gap

    def test_all_quant_modes_converge(self):
        for q in ["per_tensor", "per_group"]:
            losses = _run("olmo-7b", q, steps=40)
            assert losses[-5:].mean() < losses[:5].mean()


class TestAutomaticScalingInTraining:
    def test_auto_equals_jit_quality(self):
        """Paper Table 11: automatic scaling matches JIT accuracy."""
        auto = _run("llama2-7b", "moss", steps=50)
        cfg_jit = QuantConfig(mode="moss", weight_scaling="jit")
        cfg = get_config("llama2-7b", smoke=True).replace(quant=cfg_jit)
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=5, total_steps=50)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=8, seed=0))
        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, hp))
        jit_losses = []
        for t in range(50):
            state, m = step(state, data.batch_for_step(t))
            jit_losses.append(float(m["loss"]))
        gap = abs(auto[-10:].mean() - np.mean(jit_losses[-10:])) \
            / np.mean(jit_losses[-10:])
        assert gap < 0.03, gap

    def test_scale_states_advance_and_refresh(self):
        cfg = get_config("olmo-7b", smoke=True).replace(
            quant=QuantConfig(mode="moss", weight_scaling="auto",
                              rescale_interval=4))
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=12)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=4))
        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, hp))
        for t in range(4):
            state, _ = step(state, data.batch_for_step(t))
        # after exactly `interval` steps every counter has refreshed to 0
        assert all(int(t) == 0 for t in jax.tree.leaves(state.scale_t))
        state, _ = step(state, data.batch_for_step(4))
        assert all(int(t) == 1 for t in jax.tree.leaves(state.scale_t))


class TestCheckpointing:
    def test_save_restore_resume_exact(self, tmp_path):
        d = str(tmp_path / "ck")
        _, h1 = train("olmo-7b", steps=20, batch=4, seq=64,
                      quant="moss", ckpt_dir=d, ckpt_every=10,
                      log=lambda *a: None)
        # continue 20->30 from checkpoint
        _, h2 = train("olmo-7b", steps=30, batch=4, seq=64,
                      quant="moss", ckpt_dir=d, ckpt_every=10,
                      log=lambda *a: None)
        # uninterrupted 30-step run must match the resumed one exactly
        d2 = str(tmp_path / "ck2")
        _, h3 = train("olmo-7b", steps=30, batch=4, seq=64,
                      quant="moss", ckpt_dir=d2, ckpt_every=50,
                      log=lambda *a: None)
        resumed = dict(h2)[30]
        straight = dict(h3)[30]
        assert abs(resumed - straight) < 1e-4, (resumed, straight)

    def test_atomic_and_pruned(self, tmp_path):
        d = str(tmp_path / "ck")
        cfg = get_config("olmo-7b", smoke=True)
        hp = TrainHParams()
        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        for s in [10, 20, 30, 40]:
            ckpt.save(d, s, {"step": jnp.asarray(s)})
        kept = sorted(os.listdir(d))
        assert kept == ["step_00000020", "step_00000030",
                        "step_00000040"]          # keep last 3
        tree, s = ckpt.restore(d, {"step": jnp.asarray(0)})
        assert s == 40 and int(tree["step"]) == 40


class TestRecurrenceOracles:
    def test_rwkv_chunked_matches_stepwise(self):
        """Chunked WKV == token-by-token recurrence (exact math)."""
        from repro.models.rwkv6 import _wkv_chunked, _wkv_step

        B, T, H, D = 2, 37, 3, 8
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        r = jax.random.normal(ks[0], (B, T, H, D))
        k = jax.random.normal(ks[1], (B, T, H, D))
        v = jax.random.normal(ks[2], (B, T, H, D))
        lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, D)) * 0.3)
        lw = jnp.clip(lw, -5.0, -1e-4)
        u = jnp.full((H, D), 0.3)
        S0 = jnp.zeros((B, H, D, D))
        y_c, S_c = _wkv_chunked(r, k, v, lw, u, S0)
        S = S0
        outs = []
        for t in range(T):
            y_t, S = _wkv_step(r[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                               lw[:, t:t+1], u, S)
            outs.append(y_t)
        y_s = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(S_c), np.asarray(S),
                                   rtol=2e-4, atol=2e-4)

    def test_rglru_chunked_matches_stepwise(self):
        from repro.models.rglru import _lru_scan

        B, T, L = 2, 53, 16
        key = jax.random.PRNGKey(1)
        a = jax.nn.sigmoid(jax.random.normal(key, (B, T, L)))
        b = jax.random.normal(jax.random.fold_in(key, 1), (B, T, L))
        h0 = jax.random.normal(jax.random.fold_in(key, 2), (B, L))
        hs, h_last = _lru_scan(a, b, h0)
        h = h0
        for t in range(T):
            h = a[:, t] * h + b[:, t]
            np.testing.assert_allclose(np.asarray(hs[:, t]),
                                       np.asarray(h), rtol=1e-5,
                                       atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab=512, seq_len=32, global_batch=4, seed=7)
        a = SyntheticLM(cfg).batch_for_step(13)
        b = SyntheticLM(cfg).batch_for_step(13)
        assert (np.asarray(a["tokens"]) == np.asarray(b["tokens"])).all()

    def test_label_shift(self):
        cfg = DataConfig(vocab=512, seq_len=32, global_batch=4)
        batch = SyntheticLM(cfg).batch_for_step(0)
        assert (np.asarray(batch["tokens"][:, 1:])
                == np.asarray(batch["labels"][:, :-1])).all()
