"""Batched serving example: continuous batching over a request queue
with prefill + decode on a MOSS-quantized model — the fp8-at-rest
serving defaults: build-time pre-quantized weights (PrequantParams)
and the fp8 KV cache (docs/serving.md).

  PYTHONPATH=src python examples/serve_batched.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.launch.serve import Request, Server
from repro.models.layers import init_tree
from repro.models.transformer import model_defs


def main():
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, size=24,
                                    dtype=np.int32),
                max_new=12)
        for i in range(10)
    ]
    print(f"{len(requests)} requests, 4 decode slots "
          f"(continuous batching)")
    server = Server(cfg, params, batch_slots=4, max_len=64)
    from repro.core.runtime_flags import serve_prequant
    from repro.models.attention import resolve_kv_cache_dtype
    print(f"weights: {'pre-quantized fp8 (PrequantParams)' if server.prequant else 'in-graph quantize (REPRO_SERVE_PREQUANT=0)'}"
          f" | kv cache: {resolve_kv_cache_dtype(cfg)}")
    assert (server.prequant is not None) == (serve_prequant()
                                            and cfg.quant.quantized)
    done = server.run(requests)
    for r in done[:3]:
        print(f"request {r.rid}: prompt[:6]={r.prompt[:6].tolist()} "
              f"-> generated {r.out}")


if __name__ == "__main__":
    main()
