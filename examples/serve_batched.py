"""Batched serving example: the paged continuous-batching engine over
a request queue with mixed prompt lengths — the fp8-at-rest serving
defaults: build-time pre-quantized weights (PrequantParams), the fp8
KV cache, the fused decode-attention kernel, and per-slot depths with
floating-page block tables (docs/continuous-batching.md).  Prompts
are chunk-prefilled through the mixed decode-mode step (Scheduler
v2), interleaved with the resident rows' decode steps.  A second
wave shares a system prompt: its page-aligned prefix is stored once
and served copy-on-write, and only each request's unshared suffix
chunk-prefills (docs/paged-attention.md).

  PYTHONPATH=src python examples/serve_batched.py \
      [--metrics-out metrics.json] [--trace-out trace.json]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models.layers import init_tree
from repro.models.transformer import model_defs
from repro.serving import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the metrics-registry snapshot as JSON "
                         "at exit (docs/observability.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record engine step spans and write the "
                         "Chrome-trace JSON at exit")
    args = ap.parse_args()
    tracer = None
    if args.trace_out:
        from repro.obs.trace import get_tracer
        tracer = get_tracer().enable(path=args.trace_out)
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # mixed prompt lengths: slots at different depths coexist via the
    # per-slot length vector (no re-prefill around a shared ring idx)
    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(8, 28)),
                                    dtype=np.int32),
                max_new=12)
        for i in range(10)
    ]
    print(f"{len(requests)} requests (prompt lengths "
          f"{[r.prompt_len for r in requests]}), 4 decode slots "
          f"(paged continuous batching)")
    engine = Engine(cfg, params, num_slots=4, max_len=64)
    from repro.core.runtime_flags import serve_prequant
    from repro.models.attention import resolve_kv_cache_dtype
    print(f"weights: "
          f"{'pre-quantized fp8 (PrequantParams)' if engine.prequant else 'in-graph quantize (REPRO_SERVE_PREQUANT=0)'}"
          f" | kv cache: {resolve_kv_cache_dtype(cfg)}"
          f" | page pool: {engine.kv.allocator.num_pages} pages x "
          f"{engine.kv.allocator.page_size} tokens")
    assert (engine.prequant is not None) == (serve_prequant()
                                             and cfg.quant.quantized)
    done = engine.run(requests)
    assert all(r.done for r in done) and len(done) == len(requests)
    for r in done[:3]:
        print(f"request {r.rid}: prompt[:6]={r.prompt[:6].tolist()} "
              f"-> generated {r.out}")
    s = engine.stats()
    print(f"mean TTFT {1e3 * s['mean_ttft_s']:.1f} ms | "
          f"mean TPOT {1e3 * s['mean_tpot_s']:.1f} ms")

    # -- shared-system-prompt wave: the prefix-caching path ------------
    system_prompt = rng.integers(0, cfg.vocab, size=32, dtype=np.int32)
    wave = [
        Request(rid=100 + i,
                prompt=np.concatenate(
                    [system_prompt,
                     rng.integers(0, cfg.vocab,
                                  size=int(rng.integers(2, 8)),
                                  dtype=np.int32)]),
                max_new=8)
        for i in range(6)
    ]
    print(f"\nshared-prefix wave: {len(wave)} requests repeating a "
          f"{len(system_prompt)}-token system prompt")
    skipped_before = s["prefill_tokens_skipped"]
    done = engine.run(wave)
    assert all(r.done for r in done) and len(done) == len(wave)
    s = engine.stats()
    hits = [r for r in wave if r.prefix_pages > 0]
    # the first wave request chunk-prefills the system prompt; every
    # later one maps its pages copy-on-write and chunks only its own
    # few-token suffix
    assert len(hits) == len(wave) - 1, \
        [(r.rid, r.prefix_pages) for r in wave]
    assert (s["prefill_tokens_skipped"] - skipped_before
            == (len(wave) - 1) * len(system_prompt))
    print(f"prefix hits {len(hits)}/{len(wave)} | prefill tokens "
          f"skipped {s['prefill_tokens_skipped']} | pages shared "
          f"{s['pages_shared']} | CoW copies {s['cow_copies']} | "
          f"peak pool pages {s['peak_pool_pages']}")

    if tracer is not None:
        print(f"trace: {tracer.save()} ({len(tracer)} events)")
    if args.metrics_out:
        from repro.obs.metrics import get_registry
        with open(args.metrics_out, "w") as f:
            f.write(get_registry().to_json(indent=2))
        print(f"metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
