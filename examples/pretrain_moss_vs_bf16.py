"""End-to-end driver (paper Fig 5 reproduction at accessible scale):
pretrain a ~100M-param OLMo-style model for a few hundred steps under
BF16 and under MOSS FP8, and compare the loss curves.

  PYTHONPATH=src python examples/pretrain_moss_vs_bf16.py \
      [--steps 300] [--d-model 512] [--layers 8]

With the defaults this builds a ~100M-parameter model (d=512, 8 layers,
vocab 50304) — a real training run on CPU takes a while; use --steps 60
for a quick look.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.train import quant_from_name
from repro.train.steps import TrainHParams, init_train_state, make_train_step


def run(cfg, steps, batch, seq, label):
    hp = TrainHParams(peak_lr=6e-4, warmup_steps=max(steps // 10, 5),
                      total_steps=steps)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=0))
    state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, hp), donate_argnums=(0,))
    losses = []
    for t in range(steps):
        state, m = step(state, data.batch_for_step(t))
        losses.append(float(m["loss"]))
        if (t + 1) % max(steps // 10, 1) == 0:
            print(f"  [{label}] step {t+1:4d}  loss {losses[-1]:.4f}")
    return np.asarray(losses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    base = get_config("olmo-7b").replace(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv=args.d_model // 64, d_head=64,
        d_ff=args.d_model * 3, remat=False, attn_chunk=128)
    n_params = (base.vocab * base.d_model * 2
                + base.n_layers * (4 * base.d_model ** 2
                                   + 3 * base.d_model * base.d_ff))
    print(f"model: {n_params/1e6:.0f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    curves = {}
    for quant in ["bf16", "moss"]:
        print(f"--- {quant} ---")
        cfg = base.replace(quant=quant_from_name(quant))
        curves[quant] = run(cfg, args.steps, args.batch, args.seq, quant)

    tail = max(args.steps // 10, 5)
    b, m = curves["bf16"][-tail:].mean(), curves["moss"][-tail:].mean()
    print(f"\nfinal loss: bf16 {b:.4f} vs MOSS {m:.4f} "
          f"(gap {abs(m-b)/b*100:.2f}% — paper Fig 5: curves align)")


if __name__ == "__main__":
    main()
