"""Quickstart: MOSS two-level FP8 quantization + automatic scaling in
five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.autoscale import (init_scale_state, predicted_scale,
                                  update_scale_state)
from repro.core.formats import MOSS_CONFIG
from repro.core.linear import QT, qlinear
from repro.core.quant import quant_mx, scheme_snr
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)
    # an LLM-like activation: gaussian body + sparse strong outliers
    x = jax.random.normal(key, (512, 2048))
    x = x * (1 + 300.0 * jax.random.bernoulli(jax.random.PRNGKey(1),
                                              0.002, x.shape))

    # --- 1. two-level microscaling (paper Eqs. 2-3) -------------------
    q = quant_mx(x)                       # E4M3 values
    print(f"payload:   {q.q.dtype}, {q.q.shape}")
    print(f"level-2:   int8 E8M0 exponents, {q.sexp.shape} "
          f"({q.storage_bits_per_value():.2f} bits/value)")
    print(f"level-1:   one f32 global scale = {float(q.s):.5f}")
    print(f"SNR:       {float(scheme_snr(x, MOSS_CONFIG)):.1f} dB")

    # --- 2. the MOSS GEMM via the kernel-dispatch path ----------------
    w = jax.random.normal(jax.random.PRNGKey(2), (2048, 512)) * 0.02
    y = ops.moss_linear(x, w)
    exact = x @ w
    rel = float(jnp.linalg.norm(y.astype(jnp.float32) - exact)
                / jnp.linalg.norm(exact))
    print(f"GEMM:      rel. error vs exact = {rel:.4f}")

    # --- 3. automatic weight scaling (paper Eq. 10) -------------------
    st = init_scale_state(w, MOSS_CONFIG)
    lr = jnp.float32(3e-4)
    print(f"s_0 = {float(st.s0):.6f} (one max-reduction at init)")
    for step in range(3):
        s_t = predicted_scale(st, lr, MOSS_CONFIG)
        y = qlinear(x.astype(jnp.bfloat16), QT(w, s_t), MOSS_CONFIG)
        st = update_scale_state(st, w, MOSS_CONFIG)
        print(f"step {step}: predicted scale {float(s_t):.6f} "
              f"(no max-reduction), y finite={bool(jnp.isfinite(y).all())}")


if __name__ == "__main__":
    main()
