"""Run one MOSS FP8 train step on every assigned architecture
(--arch <id> selects one; default sweeps all ten).

  PYTHONPATH=src python examples/multiarch_smoke.py [--arch rwkv6-3b]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import ASSIGNED, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train.steps import TrainHParams, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    choices=ASSIGNED + [None], nargs="?")
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ASSIGNED

    for arch in archs:
        cfg = get_config(arch, smoke=True)
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=10)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=4))
        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, hp), donate_argnums=(0,))
        t0 = time.time()
        losses = []
        for t in range(args.steps):
            batch = data.batch_for_step(t)
            if cfg.input_mode == "embeddings":
                from repro.launch.train import _stub_embeds
                batch["embeds"] = _stub_embeds(cfg, batch["tokens"])
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        print(f"{arch:26s} [{cfg.family:7s}] losses="
              f"{['%.3f' % l for l in losses]}  ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
