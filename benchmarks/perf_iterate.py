"""§Perf hillclimbing driver: run tagged dry-run variants for the three
chosen cells and print the before/after roofline terms per iteration.

  PYTHONPATH=src python -m benchmarks.perf_iterate [--cell N]

Each variant is one hypothesis from the iteration log in EXPERIMENTS.md
§Perf; artifacts land in experiments/dryrun/ with __<tag> suffixes so
the baselines stay untouched.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# (arch, shape, tag, preset, overrides)
CELLS = {
    # most collective-bound + worst dense roofline fraction
    "phi3mini": [
        ("phi3-mini-3.8b", "train_4k", "__p1_fsdp", "fsdp", {}),
        ("phi3-mini-3.8b", "train_4k", "__p2_fsdp_mb1", "fsdp",
         {"microbatches": 1}),
        ("phi3-mini-3.8b", "train_4k", "__p3_tpsp", "tp-sp", {}),
        ("phi3-mini-3.8b", "train_4k", "__p4_fsdp_mb2", "fsdp",
         {"microbatches": 2}),
        # p5: + grad reduce-scatter (now default) + bf16 weight gathers
        ("phi3-mini-3.8b", "train_4k", "__p5_fsdp_mb1_bf16w", "fsdp",
         {"microbatches": 1, "weight_cast_bf16": True}),
        # p6: grad-RS only (isolates the two effects)
        ("phi3-mini-3.8b", "train_4k", "__p6_fsdp_mb1_rs", "fsdp",
         {"microbatches": 1}),
    ],
    # the paper's own regime: FP8 MoE GEMMs + MLA; most collective-heavy
    "deepseek": [
        ("deepseek-v2-lite-16b", "train_4k", "__p1_fsdp", "fsdp", {}),
        ("deepseek-v2-lite-16b", "train_4k", "__p2_fsdp_mb4", "fsdp",
         {"microbatches": 4}),
        ("deepseek-v2-lite-16b", "train_4k", "__p3_fsdp_cap10", "fsdp",
         {"microbatches": 4, "capacity_factor": 1.0}),
        # p4: mb8 (fits HBM) + grad-RS + bf16 weight gathers
        ("deepseek-v2-lite-16b", "train_4k", "__p4_fsdp_bf16w", "fsdp",
         {"microbatches": 8, "weight_cast_bf16": True}),
    ],
    # memory-bound serving representative
    "stablelm_decode": [
        ("stablelm-12b", "decode_32k", "__p1_kvfp8", "2d",
         {"kv_cache_dtype": "fp8"}),
        ("stablelm-12b", "decode_32k", "__p2_kvfp8_bf16w", "2d",
         {"kv_cache_dtype": "fp8", "serve_params_dtype": "bf16"}),
        ("stablelm-12b", "decode_32k", "__p3_bf16w", "2d",
         {"serve_params_dtype": "bf16"}),
    ],
}


def summarize(path):
    from benchmarks.roofline import analyze

    rec = json.load(open(path))
    if rec["status"] != "ok":
        return f"{rec['status']}: {rec.get('error','')[:120]}"
    a = analyze(rec)
    return (f"comp {a['compute_s']:.3f}s mem {a['memory_s']:.3f}s "
            f"coll {a['collective_s']:.3f}s dom={a['dominant']} "
            f"roofline={a['roofline_fraction']:.4f} "
            f"hbm={rec['memory']['total_per_device']/2**30:.1f}GiB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS))
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell

    todo = ([args.cell] if args.cell else list(CELLS))
    for name in todo:
        variants = CELLS[name]
        arch, shape = variants[0][0], variants[0][1]
        base = f"experiments/dryrun/{arch}__{shape}__pod16x16.json"
        if os.path.exists(base):
            print(f"[{name}] baseline   : {summarize(base)}", flush=True)
        for arch, shape, tag, preset, ov in variants:
            rec = run_cell(arch, shape, multi_pod=False,
                           out_dir="experiments/dryrun", preset=preset,
                           overrides=dict(ov), tag=tag)
            path = (f"experiments/dryrun/{arch}__{shape}__pod16x16"
                    f"{tag}.json")
            print(f"[{name}] {tag[2:]:11s}: {summarize(path)}",
                  flush=True)


if __name__ == "__main__":
    main()
