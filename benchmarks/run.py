"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  CPU-runtime caveat
(EXPERIMENTS.md): FP8 is emulated on this container, so wall-clock rows
measure the *emulation*; the paper's speedup evidence is carried by the
structural rows (bytes, scaling-op counts, SNR, roofline terms), which
are runtime-independent.
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


# rows accumulate here so --json can emit the whole run as machine-
# readable records (the perf-trajectory artifact uploaded by CI)
_ROWS: list[dict] = []


def row(name, us, derived=""):
    _ROWS.append({"name": name, "us_per_call": round(us, 1),
                  "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# Paper Table 1: time to produce per-tensor weight scales —
# just-in-time (max-reduction over the tensor) vs automatic (Eq. 10).
# ---------------------------------------------------------------------------


def bench_table1_autoscale():
    from repro.core.autoscale import ScaleState, predicted_scale
    from repro.core.formats import MOSS_CONFIG

    sizes = [(11008, 16384), (11008, 8192), (4096, 12288), (4096, 4096)]
    for shape in sizes:
        w = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)

        jit_scale = jax.jit(lambda w: jnp.max(jnp.abs(w)) / 448.0)
        us_jit = _timeit(jit_scale, w)

        st = ScaleState(s0=jnp.float32(0.01),
                        steps_since=jnp.asarray(17, jnp.int32))
        auto = jax.jit(lambda st, lr: predicted_scale(st, lr,
                                                      MOSS_CONFIG))
        us_auto = _timeit(auto, st, jnp.float32(3e-4))
        # derived: bytes the JIT path must read from HBM that the
        # automatic path does not (the paper's Table 1 mechanism)
        saved = int(np.prod(shape)) * 4
        row(f"table1_jit_scaling_{shape[0]}x{shape[1]}", us_jit,
            f"reads_{saved}B")
        row(f"table1_auto_scaling_{shape[0]}x{shape[1]}", us_auto,
            "reads_0B_constant_time")


# ---------------------------------------------------------------------------
# Paper Table 2/3: training throughput, MOSS vs BF16 vs COAT-style —
# smoke-scale wall clock + structural accounting.
# ---------------------------------------------------------------------------


def bench_table2_throughput(B: int = 8, S: int = 128, iters: int = 5):
    from repro.configs.registry import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.train import quant_from_name
    from repro.train.steps import (TrainHParams, init_train_state,
                                   make_train_step)

    for quant in ["bf16", "per_tensor", "per_group", "moss"]:
        cfg = get_config("olmo-7b", smoke=True).replace(
            quant=quant_from_name(quant))
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=5, total_steps=100)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=S,
                                      global_batch=B))
        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, hp), donate_argnums=(0,))
        state, _ = step(state, data.batch_for_step(0))   # compile
        t0 = time.perf_counter()
        for i in range(iters):
            state, m = step(state, data.batch_for_step(i + 1))
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / iters
        row(f"table2_train_step_{quant}", dt * 1e6,
            f"tokens_per_s_{B*S/dt:.0f}_cpu_emulation")


# ---------------------------------------------------------------------------
# Paper Table 5: activation-memory accounting — bytes saved for the
# backward pass under bf16 vs MOSS fp8 residuals.
# ---------------------------------------------------------------------------


def bench_table5_memory_comm():
    from repro.configs.registry import get_config
    from repro.launch.train import quant_from_name
    from repro.models.layers import (abstract_tree, quant_mask_tree,
                                     wrap_qt_nojit)
    from repro.models.transformer import ce_loss, forward, model_defs

    cfg0 = get_config("llama2-7b", smoke=True)
    B, S = 4, 256
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}

    results = {}
    for quant in ["bf16", "moss"]:
        cfg = cfg0.replace(quant=quant_from_name(quant), remat=False)
        defs = model_defs(cfg)
        params = abstract_tree(defs)

        def loss_fn(params, cfg=cfg, defs=defs):
            qp = wrap_qt_nojit(params, quant_mask_tree(defs))
            logits, _, _ = forward(cfg, cfg.quant, qp, batch,
                                   mode="train")
            return ce_loss(cfg, logits, batch["labels"])

        res_shapes = jax.eval_shape(
            lambda p: jax.vjp(loss_fn, p)[1], params)
        leaves = [l for l in jax.tree.leaves(res_shapes)
                  if hasattr(l, "shape")]
        total = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                    for l in leaves)
        results[quant] = total
        row(f"table5_residual_bytes_{quant}", 0.0, f"{total}B")
    ratio = results["bf16"] / max(results["moss"], 1)
    row("table5_residual_saving", 0.0, f"{ratio:.2f}x")


# ---------------------------------------------------------------------------
# Paper Table 6: quantized GEMM comparison at the paper's shapes.
# ---------------------------------------------------------------------------


def bench_table6_gemm():
    from repro.core.quant import (MxQ, PerGroupQ, PerTensorQ, group_gemm,
                                  mx_gemm, pt_gemm, quant_mx,
                                  quant_per_group, quant_per_tensor)

    shapes = [(2048, 7168, 4096), (4096, 2048, 7168), (4096, 4096, 8192)]
    for m, n, k in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n),
                              jnp.float32) * 0.02
        xq_mx = quant_mx(x)
        xq_pg = quant_per_group(x, 128)
        xq_pt = quant_per_tensor(x)
        wq = quant_per_tensor(w)

        f_mx = jax.jit(lambda q, e, s: mx_gemm(MxQ(q, e, s), wq,
                                               jnp.bfloat16))
        us_mx = _timeit(f_mx, xq_mx.q, xq_mx.sexp, xq_mx.s, iters=3,
                        warmup=1)
        f_pg = jax.jit(lambda q, s: group_gemm(PerGroupQ(q, s), wq,
                                               jnp.bfloat16))
        us_pg = _timeit(f_pg, xq_pg.q, xq_pg.s, iters=3, warmup=1)
        f_pt = jax.jit(lambda q, s: pt_gemm(PerTensorQ(q, s), wq,
                                            jnp.bfloat16))
        us_pt = _timeit(f_pt, xq_pt.q, xq_pt.s, iters=3, warmup=1)

        # structural: in-loop VPU dequant multiplies of the (bm,bn)
        # accumulator per output element (the cost MOSS removes)
        row(f"table6_gemm_moss_{m}x{n}x{k}", us_mx,
            "acc_rescales_per_output_1(epilogue)")
        row(f"table6_gemm_coat_{m}x{n}x{k}", us_pg,
            f"acc_rescales_per_output_{k//128}(inloop)")
        row(f"table6_gemm_te_{m}x{n}x{k}", us_pt,
            "acc_rescales_per_output_1(epilogue)")


# ---------------------------------------------------------------------------
# Paper Table 7: SNR of quantization schemes on LLM-like activations.
# ---------------------------------------------------------------------------


def bench_table7_snr():
    from repro.core.formats import (MOSS_CONFIG, PER_GROUP_CONFIG,
                                    PER_TENSOR_CONFIG)
    from repro.core.quant import (model_snr_moss, model_snr_per_group,
                                  model_snr_per_tensor, scheme_snr)

    layers = {
        "attention_output": (300.0, 0.002),
        "ffn_intermediate": (800.0, 0.001),
        "layernorm_input": (100.0, 0.005),
    }
    for name, (scale, dens) in layers.items():
        k1, k2 = jax.random.split(jax.random.PRNGKey(hash(name) % 2**31))
        x = jax.random.normal(k1, (256, 2048), jnp.float32) \
            * (1 + scale * jax.random.bernoulli(k2, dens, (256, 2048)))
        t = float(model_snr_per_tensor(x))
        g = float(model_snr_per_group(x))
        mm_ = float(model_snr_moss(x))
        row(f"table7_modelSNR_{name}", 0.0,
            f"pt_{t:.1f}dB_pg_{g:.1f}dB_moss_{mm_:.1f}dB")
        tm = float(scheme_snr(x, PER_TENSOR_CONFIG))
        gm = float(scheme_snr(x, PER_GROUP_CONFIG))
        mq = float(scheme_snr(x, MOSS_CONFIG))
        row(f"table7_measuredSNR_{name}", 0.0,
            f"pt_{tm:.1f}dB_pg_{gm:.1f}dB_moss_{mq:.1f}dB")


# ---------------------------------------------------------------------------
# Paper Table 9/10: rescale-interval ablation + scaling strategies.
# ---------------------------------------------------------------------------


def bench_table9_interval():
    from repro.configs.registry import get_config
    from repro.core.formats import QuantConfig
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.train.steps import (TrainHParams, init_train_state,
                                   make_train_step)

    for name, scaling, interval in [("jit", "jit", 1),
                                    ("auto100", "auto", 100),
                                    ("auto500", "auto", 500),
                                    ("delayed", "delayed", 1)]:
        cfg = get_config("llama2-7b", smoke=True).replace(
            quant=QuantConfig(mode="moss", weight_scaling=scaling,
                              rescale_interval=interval))
        hp = TrainHParams(peak_lr=1e-3, warmup_steps=5, total_steps=60)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=8))
        state = init_train_state(cfg, hp, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, hp), donate_argnums=(0,))
        losses = []
        t0 = time.perf_counter()
        for t in range(30):
            state, m = step(state, data.batch_for_step(t))
            losses.append(float(m["loss"]))
        dt = (time.perf_counter() - t0) / 30
        row(f"table9_interval_{name}", dt * 1e6,
            f"final_loss_{np.mean(losses[-5:]):.4f}")


# ---------------------------------------------------------------------------
# Kernel-dispatch timing: the same MOSS GEMM through each backend of
# repro.kernels.dispatch (ref = jnp reference; interpret = Pallas
# kernels under the interpreter — kernel-path validation, not a speed
# claim; pallas-native requires a TPU).
# ---------------------------------------------------------------------------


def bench_dispatch_backends(m=256, n=256, k=1024):
    from repro.core.quant import quant_mx, quant_per_tensor
    from repro.core.runtime_flags import kernel_backend
    from repro.kernels import dispatch

    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n),
                          jnp.float32) * 0.02
    xq, wq = quant_mx(x), quant_per_tensor(w)
    backends = ["ref", "interpret"]
    if kernel_backend() == "pallas":
        backends.append("pallas")
    for backend in backends:
        fn = jax.jit(lambda q, e, s: dispatch.mx_matmul(
            type(xq)(q, e, s), wq, jnp.bfloat16, backend=backend))
        us = _timeit(fn, xq.q, xq.sexp, xq.s, iters=3, warmup=1)
        row(f"dispatch_mx_matmul_{backend}_{m}x{n}x{k}", us)
        ffn = jax.jit(lambda xx: dispatch.fused_quant_matmul(
            xx, wq, out_dtype=jnp.bfloat16, backend=backend)[0])
        us = _timeit(ffn, x, iters=3, warmup=1)
        row(f"dispatch_fused_quant_matmul_{backend}_{m}x{n}x{k}", us)


# ---------------------------------------------------------------------------
# Grouped-expert MoE GEMM: one ragged kernel for every expert vs the
# legacy per-expert vmapped path.  Wall clock is CPU emulation; the
# structural columns (kernel launches and level-1 amax reductions per
# MoE block) carry the speedup mechanism.
# ---------------------------------------------------------------------------


def bench_moe_grouped(B: int = 2, S: int = 128, iters: int = 5):
    from repro.configs.registry import get_config
    from repro.models import moe
    from repro.models.layers import (init_tree, quant_mask_tree,
                                     wrap_qt_nojit)

    # moe_decode_dense=False: without it the small-T single-device
    # train path short-circuits to the masked dense-experts combine and
    # the A/B would measure the dense path twice
    cfg = get_config("phi3.5-moe-42b-a6.6b",
                     smoke=True).replace(moe_decode_dense=False)
    qcfg = cfg.quant
    defs = moe.moe_defs(cfg)
    params = init_tree(defs, jax.random.PRNGKey(0))
    qp = wrap_qt_nojit(params, quant_mask_tree(defs))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.bfloat16)
    e = cfg.n_experts
    us = {}
    prior = os.environ.get("REPRO_MOE_EXPERTS")
    try:
        for path in ("grouped", "vmapped"):
            os.environ["REPRO_MOE_EXPERTS"] = path

            def block(x, path=path):
                return moe.moe_block(cfg, qp, x, qcfg, mode="train")[0]

            us[path] = _timeit(jax.jit(block), x, iters=iters, warmup=2)
    finally:
        if prior is None:
            os.environ.pop("REPRO_MOE_EXPERTS", None)
        else:
            os.environ["REPRO_MOE_EXPERTS"] = prior
    row("moe_grouped_vs_vmapped", us["grouped"],
        f"vmapped_us_{us['vmapped']:.1f}"
        f"_launches_3_vs_{3 * e}_amax_reductions_1_vs_{e}")


# ---------------------------------------------------------------------------
# Pre-quantized serving: decode step time + structural op counts with
# in-graph weight quantization vs build-time fp8 weights
# (PrequantParams).  The op counts (weight fp8 casts, max-reductions in
# the decode jaxpr) are the mechanism; CPU wall clock is emulation.
# ---------------------------------------------------------------------------


def bench_serve_prequant(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.core.formats import PER_GROUP_CONFIG
    from repro.core.introspect import (count_fp8_casts, count_primitive,
                                       weight_slice_sizes)
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.train.steps import (make_decode_step, make_prefill_step,
                                   prequantize_params, serve_weight_scales)

    for mode, quant in (("per_group", PER_GROUP_CONFIG), ("moss", None)):
        cfg = get_config(arch, smoke=True)
        if quant is not None:
            cfg = cfg.replace(quant=quant)
        params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab)
        tok1 = toks[:, :1]
        wsizes = weight_slice_sizes(cfg)

        stats = {}
        for tag in ("ingraph", "prequant"):
            if tag == "prequant":
                pq = prequantize_params(cfg, params)
                p, scales = pq.qweights, pq.scales
            else:
                p, scales = params, serve_weight_scales(cfg, params)
            pre = jax.jit(make_prefill_step(cfg, 32, scales=scales))
            _, caches = pre(p, {"tokens": toks})
            step = make_decode_step(cfg, scales=scales)
            jx = jax.make_jaxpr(step)(p, caches, tok1)
            dec = jax.jit(step)
            us = _timeit(lambda c: dec(p, c, tok1)[0], caches,
                         iters=10, warmup=2)
            stats[tag] = (us, count_primitive(jx, "reduce_max"),
                          count_fp8_casts(jx, wsizes))
        us_pq, amax_pq, wc_pq = stats["prequant"]
        us_no, amax_no, wc_no = stats["ingraph"]
        row(f"serve_prequant_decode_{mode}", us_pq,
            f"ingraph_us_{us_no:.1f}_amax_{amax_pq}_vs_{amax_no}"
            f"_weight_fp8_casts_{wc_pq}_vs_{wc_no}")


# ---------------------------------------------------------------------------
# Reduction-free decode: delayed (calibrated) activation scales vs the
# just-in-time path, per recipe — decode-step wall clock plus the
# structural mechanism: quantization reductions (reduce_max feeding an
# fp8 cast, core.introspect.count_quant_reductions) removed from the
# decode jaxpr.  bf16 KV cache so the counts isolate the activation
# quantizers (the fp8 cache keeps its 2 storage-format reductions —
# docs/serving.md).
# ---------------------------------------------------------------------------


def bench_decode_reduction_free(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.core.actscale import calibrate_act_scales
    from repro.core.formats import (MOSS_CONFIG, PER_GROUP_CONFIG,
                                    PER_TENSOR_CONFIG)
    from repro.core.introspect import count_quant_reductions
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.train.steps import (make_decode_step, make_prefill_step,
                                   prequantize_params)

    for mode, quant in (("per_tensor", PER_TENSOR_CONFIG),
                        ("per_group", PER_GROUP_CONFIG),
                        ("moss", MOSS_CONFIG)):
        cfg = get_config(arch, smoke=True).replace(quant=quant,
                                                   kv_cache_dtype="bf16")
        params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
        pq = prequantize_params(cfg, params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab)
        tok1 = toks[:, :1]
        act = calibrate_act_scales(cfg, pq.qweights, pq.scales)
        pre = jax.jit(make_prefill_step(cfg, 32, scales=pq.scales))
        _, caches = pre(pq.qweights, {"tokens": toks})

        stats = {}
        for tag, a in (("jit", None), ("delayed", act)):
            step = make_decode_step(cfg, scales=pq.scales, act_scales=a)
            jx = jax.make_jaxpr(step)(pq.qweights, caches, tok1)
            dec = jax.jit(step)
            us = _timeit(lambda c: dec(pq.qweights, c, tok1)[0], caches,
                         iters=10, warmup=2)
            stats[tag] = (us, count_quant_reductions(jx))
        us_d, nred_d = stats["delayed"]
        us_j, nred_j = stats["jit"]
        row(f"serve_delayed_decode_{mode}", us_d,
            f"jit_us_{us_j:.1f}_quant_reductions_{nred_d}_vs_{nred_j}")


# ---------------------------------------------------------------------------
# Fused decode attention over the fp8 KV cache: decode step wall clock
# for the kernel path (CPU default resolves to the ref oracle — same
# math as the einsum path, so "no slower" holds structurally and in
# wall clock) vs the REPRO_DECODE_ATTN=einsum fallback, plus the
# jaxpr-level mechanism: cache-sized fp8 dequant upcasts and cache
# dots removed from the decode graph (counted on the interpret-backend
# trace, where the fused pallas_call is actually in the graph).
# ---------------------------------------------------------------------------


def bench_decode_attn(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.core.introspect import (count_dot_general_over,
                                       count_fp8_dequant_upcasts,
                                       count_primitive,
                                       kv_cache_slice_sizes)
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.train.steps import (make_decode_step, make_prefill_step,
                                   prequantize_params)

    # B=2 keeps the cache slice size (B·KV·C·Dh = 8192) disjoint from
    # every weight-slice size, so the counters see only the cache ops
    cfg = get_config(arch, smoke=True)           # fp8 cache default
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab)
    pq = prequantize_params(cfg, params)
    pre = jax.jit(make_prefill_step(cfg, 32, scales=pq.scales))
    _, caches = pre(pq.qweights, {"tokens": toks})
    tok1 = toks[:, :1]
    sizes = kv_cache_slice_sizes(cfg, 2, 32)

    knobs = ("REPRO_DECODE_ATTN", "REPRO_KERNELS")
    prior = {k: os.environ.get(k) for k in knobs}
    us, counts = {}, {}
    try:
        for tag, env in (("kernel", {}),
                         ("einsum", {"REPRO_DECODE_ATTN": "einsum"})):
            for k in knobs:
                os.environ.pop(k, None)
            os.environ.update(env)
            dec = jax.jit(make_decode_step(cfg, scales=pq.scales))
            # min-of-3: on the CPU default both paths resolve to the
            # same ref math, so wall-clock differences are pure noise
            us[tag] = min(_timeit(lambda c: dec(pq.qweights, c,
                                                tok1)[0],
                                  caches, iters=10, warmup=2)
                          for _ in range(3))
            # structural counts from the interpret-backend trace —
            # the linear GEMMs become pallas_calls on BOTH paths, so
            # the deltas isolate the decode-attention mechanism
            os.environ["REPRO_KERNELS"] = "interpret"
            step = make_decode_step(cfg, scales=pq.scales)
            jx = jax.make_jaxpr(step)(pq.qweights, caches, tok1)
            counts[tag] = (count_fp8_dequant_upcasts(jx, sizes),
                           count_dot_general_over(jx, sizes),
                           count_primitive(jx, "pallas_call"))
            if tag == "kernel":
                dec_i = jax.jit(step)
                us["interpret"] = _timeit(
                    lambda c: dec_i(pq.qweights, c, tok1)[0], caches,
                    iters=3, warmup=1)
    finally:
        for k, v in prior.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    up_k, dot_k, pc_k = counts["kernel"]
    up_e, dot_e, pc_e = counts["einsum"]
    row("decode_attn_fused_vs_einsum", us["kernel"],
        f"einsum_us_{us['einsum']:.1f}"
        f"_interpret_us_{us['interpret']:.1f}"
        f"_cache_dequant_upcasts_{up_k}_vs_{up_e}"
        f"_cache_dots_{dot_k}_vs_{dot_e}"
        f"_fused_launches_{pc_k - pc_e}")


# ---------------------------------------------------------------------------
# Paged continuous batching: tok/s + mean TTFT on a mixed-length
# request trace, paged engine vs legacy contiguous-ring Server.  CPU
# wall clock is emulation; the structural columns (decode batch sizes,
# page-pool accounting, engine steps) carry the mechanism — the paged
# engine retires finished slots from the decode batch and admits
# mixed-depth requests without re-prefill (docs/continuous-batching.md).
# ---------------------------------------------------------------------------


def bench_serve_continuous(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.launch.serve import Server
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.serving import Engine, Request

    cfg = get_config(arch, smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = [8, 24, 12, 30, 16, 20, 10, 28]       # mixed-length trace
    max_new, slots, max_len = 8, 4, 48

    def trace(rid0):
        return [Request(rid=rid0 + i,
                        prompt=rng.integers(0, cfg.vocab, size=n,
                                            dtype=np.int32),
                        max_new=max_new)
                for i, n in enumerate(lens)]

    stats = {}
    for tag in ("paged", "legacy"):
        # the warmup pass compiles prefill buckets + per-row-count
        # decode steps ON THE SAME INSTANCE (jit caches live on the
        # built step callables), so the timed pass measures steady
        # state
        if tag == "paged":
            drv = Engine(cfg, params, slots, max_len=max_len)
            serve = lambda rr: drv.run(rr, log=None)
        else:
            drv = Server(cfg, params, slots, max_len=max_len)
            serve = lambda rr: drv.run(rr, log=lambda *a: None)
        for run in ("warmup", "timed"):
            reqs = trace(0 if run == "warmup" else 100)
            t0 = time.perf_counter()
            serve(reqs)
            dt = time.perf_counter() - t0
        if tag == "paged":
            # metrics over the timed trace only (warmup paid compiles)
            ttft = float(np.mean([r.ttft for r in reqs]))
            extra = (f"_mean_ttft_ms_{1e3 * ttft:.0f}"
                     f"_pages_{drv.kv.allocator.num_pages}")
        else:
            extra = ""
        toks = sum(len(r.out) for r in reqs)
        stats[tag] = (dt / toks * 1e6, toks / dt, extra)
    us_p, tps_p, extra_p = stats["paged"]
    us_l, tps_l, _ = stats["legacy"]
    row("serve_continuous_paged_vs_legacy", us_p,
        f"tok_s_{tps_p:.1f}_legacy_tok_s_{tps_l:.1f}"
        f"_legacy_us_per_tok_{us_l:.1f}{extra_p}"
        f"_trace_{len(lens)}reqs_mixed_{min(lens)}to{max(lens)}")


# ---------------------------------------------------------------------------
# Prefix caching: a shared-system-prompt trace (every request repeats
# the same page-aligned prefix) served with the copy-on-write prefix
# cache vs cold (REPRO_PREFIX_CACHE-off equivalent).  CPU wall clock
# is emulation; the structural columns — prefill tokens skipped,
# physical pages shared, peak pool pages, CoW copies — carry the
# mechanism (docs/paged-attention.md).
# ---------------------------------------------------------------------------


def bench_serve_prefix(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.serving import Engine, Request

    cfg = get_config(arch, smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # smoke scale of the 16-request/512-token-system-prompt scenario:
    # 8 requests sharing a 64-token (4-page) prefix + short distinct
    # tails, 4 slots
    n_reqs, prefix_tokens, max_new, slots, max_len = 8, 64, 6, 4, 96
    tails = [5, 8, 3, 7, 4, 6, 2, 8]

    def trace(rid0, prefix):
        return [Request(rid=rid0 + i,
                        prompt=np.concatenate(
                            [prefix, rng.integers(0, cfg.vocab, size=n,
                                                  dtype=np.int32)]),
                        max_new=max_new)
                for i, n in enumerate(tails[:n_reqs])]

    stats = {}
    for tag in ("shared", "cold"):
        eng = Engine(cfg, params, slots, max_len=max_len,
                     prefix_cache=(tag == "shared"))
        for run in ("warmup", "timed"):
            # warmup pays the jit compiles on a DIFFERENT prefix (no
            # cross-run hits); timed serves the shared-prompt trace
            prefix = rng.integers(0, cfg.vocab, size=prefix_tokens,
                                  dtype=np.int32)
            reqs = trace(0 if run == "warmup" else 100, prefix)
            skipped0 = eng.prefill_tokens_skipped
            shared0 = eng.pages_shared
            hits0 = eng.prefix_hits
            t0 = time.perf_counter()
            eng.run(reqs, log=None)
            dt = time.perf_counter() - t0
            eng.prune_finished()
        toks = sum(len(r.out) for r in reqs)
        stats[tag] = (dt / toks * 1e6, toks / dt, eng,
                      eng.prefill_tokens_skipped - skipped0,
                      eng.pages_shared - shared0,
                      eng.prefix_hits - hits0)
    us_s, tps_s, eng_s, skipped, shared, hits = stats["shared"]
    us_c, tps_c = stats["cold"][:2]
    row("serve_prefix_shared_vs_cold", us_s,
        f"tok_s_{tps_s:.1f}_cold_tok_s_{tps_c:.1f}"
        f"_cold_us_per_tok_{us_c:.1f}"
        f"_prefill_tokens_skipped_{skipped}"
        f"_pages_shared_{shared}"
        f"_prefix_hits_{hits}"
        f"_cow_copies_{eng_s.kv.cow_copies}"
        f"_peak_pool_pages_{eng_s.kv.allocator.peak_used}"
        f"_trace_{n_reqs}reqs_prefix_{prefix_tokens}tok")


# ---------------------------------------------------------------------------
# Scheduler v2 under heavy traffic: seeded Poisson arrivals with long-
# tail (Pareto) prompt lengths and a mid-trace burst, served open-
# loop.  A/B on the SAME seeded eval trace: v2 (chunked prefill +
# preemption + usage admission, the default) vs v1 (whole-prompt B=1
# bucketed prefill, worst-case reservation admission —
# REPRO_CHUNKED_PREFILL=0 REPRO_PREEMPTION=0).  The warmup trace uses
# a DIFFERENT seed on purpose: real traffic shifts, and v1 compiles a
# fresh prefill step for every 16-token prompt bucket it meets, so the
# eval trace's tail lengths hit v1 with multi-second jit stalls mid-
# serving and park every resident decode behind a B=1 long-prompt
# prefill.  v2's one mixed-step chunk shape is warm after any traffic
# — THE structural claim of chunked prefill.  CPU wall clock is
# emulation; the compile-stall asymmetry it surfaces is not (the
# prefill_shapes column counts v1's per-bucket compiles; v2 has 0).
# ---------------------------------------------------------------------------


def bench_serve_slo(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.serving import Engine, Request

    cfg = get_config(arch, smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    n_reqs, max_new, slots, max_len, pages = 14, 8, 4, 128, 18

    def trace(rid0, seed):
        rng = np.random.default_rng(seed)
        reqs, t = [], 0.0
        for i in range(n_reqs):
            # Poisson arrivals (exponential gaps) + a 3-request burst
            # landing together mid-trace; prompt lengths are long-
            # tailed (Pareto): mostly short, occasionally near-max
            if i not in (6, 7, 8):                # the burst
                t += float(rng.exponential(0.02))
            n = int(np.clip(6 + rng.pareto(1.5) * 10, 6,
                            max_len - max_new - 1))
            reqs.append(Request(
                rid=rid0 + i,
                prompt=rng.integers(0, cfg.vocab, size=n,
                                    dtype=np.int32),
                max_new=max_new, arrival_time=t))
        return reqs

    def pct(vals, q):
        return float(np.percentile([v for v in vals if v is not None],
                                   q))

    stats = {}
    for tag in ("v2", "v1"):
        env = {} if tag == "v2" else {"REPRO_CHUNKED_PREFILL": "0",
                                      "REPRO_PREEMPTION": "0"}
        saved = {k: os.environ.get(k) for k in env}
        os.environ.update(env)
        try:
            eng = Engine(cfg, params, slots, max_len=max_len,
                         num_pages=pages, prefix_cache=False)
            assert eng.chunked == (tag == "v2")
            for run, seed in (("warmup", 3), ("timed", 7)):
                # warmup serves a same-distribution, different-seed
                # trace on the same engine instance (steady state for
                # every shape that trace happens to cover); timed
                # serves the shared eval trace
                reqs = trace(0 if run == "warmup" else 100, seed)
                t0 = time.perf_counter()
                eng.run(reqs, log=None)
                dt = time.perf_counter() - t0
                eng.prune_finished()
            toks = sum(len(r.out) for r in reqs)
            try:
                prefill_shapes = eng.prefill._cache_size()
            except Exception:       # jit cache introspection moved
                prefill_shapes = -1
            stats[tag] = {
                "us": dt / toks * 1e6, "tok_s": toks / dt,
                "p50_ttft": pct([r.ttft for r in reqs], 50),
                "p99_ttft": pct([r.ttft for r in reqs], 99),
                "p50_tpot": pct([r.tpot for r in reqs], 50),
                "p99_tpot": pct([r.tpot for r in reqs], 99),
                "preempt": eng.preemptions,
                "chunks": eng.chunk_prefill_steps,
                "prefill_shapes": prefill_shapes,
            }
        finally:
            for k, v in saved.items():
                (os.environ.pop(k, None) if v is None
                 else os.environ.__setitem__(k, v))
    s2, s1 = stats["v2"], stats["v1"]
    row("serve_slo_v2_vs_v1", s2["us"],
        f"tok_s_{s2['tok_s']:.1f}_v1_tok_s_{s1['tok_s']:.1f}"
        f"_p99_ttft_ms_{1e3 * s2['p99_ttft']:.0f}"
        f"_v1_p99_ttft_ms_{1e3 * s1['p99_ttft']:.0f}"
        f"_p50_ttft_ms_{1e3 * s2['p50_ttft']:.0f}"
        f"_v1_p50_ttft_ms_{1e3 * s1['p50_ttft']:.0f}"
        f"_p99_tpot_ms_{1e3 * s2['p99_tpot']:.0f}"
        f"_v1_p99_tpot_ms_{1e3 * s1['p99_tpot']:.0f}"
        f"_prefill_shapes_{s2['prefill_shapes']}"
        f"_vs_{s1['prefill_shapes']}"
        f"_chunk_steps_{s2['chunks']}"
        f"_preemptions_{s2['preempt']}"
        f"_trace_{n_reqs}reqs_poisson_burst_pool_{pages}pages")


# ---------------------------------------------------------------------------
# Speculative multi-token decode: a repeated-suffix trace (each prompt
# tiles its own motif — the regime prompt-lookup drafting targets)
# served A/B: plain decode vs speculative verify at k in {2, 4}.  The
# speculative engines use a replay draft through the ``ModelDraft``
# hook (the baseline's own outputs, i.e. a perfectly-aligned small
# model — upper-bound acceptance), then the same engine re-serves the
# trace with the host-side ``NgramDraft`` for a model-free acceptance
# column.  Greedy verification guarantees token-for-token identical
# output for EVERY draft source, asserted here.  CPU wall clock is
# emulation; the structural columns carry the mechanism: accepted
# tokens per verify step (> 1 means each fp8-cache page read now
# produces multiple committed tokens) and the verify-step jaxpr's
# quantization-reduction count (the batched-query graph keeps the
# serving contract: only the 2 per-position K/V storage-write amaxes;
# docs/speculative-decoding.md).
# ---------------------------------------------------------------------------


def bench_spec_decode(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.core.introspect import count_quant_reductions
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.serving import Engine, ModelDraft, NgramDraft, Request
    from repro.train.steps import make_prefill_step, make_verify_step

    cfg = get_config(arch, smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_reqs, max_new, slots, max_len = 4, 12, 4, 64
    # 16-token prompts x 4 rows = one 64-token prefill chunk: all rows
    # admit together and stay lockstep, so per-step columns divide by
    # a constant batch (prefix cache off for the same reason — a
    # timed-trace prefix hit would change the admission timeline vs
    # warmup)
    prompts = [np.tile(rng.integers(0, cfg.vocab, size=4,
                                    dtype=np.int32), 4)
               for _ in range(n_reqs)]

    def trace(rid0):
        return [Request(rid=rid0 + i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]

    def serve(eng, rid0):
        reqs = trace(rid0)
        t0 = time.perf_counter()
        eng.run(reqs, log=None)
        dt = time.perf_counter() - t0
        eng.prune_finished()
        return reqs, dt

    # plain-decode baseline; its outputs double as the replay draft
    base = Engine(cfg, params, slots, max_len=max_len,
                  chunk_tokens=64, prefix_cache=False,
                  spec_decode=False)
    serve(base, 0)                               # warmup (compiles)
    breqs, bdt = serve(base, 100)
    btoks = sum(len(r.out) for r in breqs)
    truth = {tuple(int(x) for x in p): list(r.out)
             for p, r in zip(prompts, breqs)}

    def replay(ctx, k):
        for p, out in truth.items():
            if tuple(ctx[:len(p)]) == p:
                done = len(ctx) - len(p)
                return out[done:done + k]
        return []

    for k in (2, 4):
        eng = Engine(cfg, params, slots, max_len=max_len,
                     chunk_tokens=64, prefix_cache=False,
                     spec_decode=True, draft=ModelDraft(replay),
                     spec_k=k)
        assert eng.spec, "spec gate off on the smoke serving config"
        serve(eng, 0)                            # warmup
        s0 = eng.sched.summary()
        reqs, dt = serve(eng, 100)
        s1 = eng.sched.summary()
        toks = sum(len(r.out) for r in reqs)
        for b, r in zip(breqs, reqs):
            assert b.out == r.out, "speculative output diverged"
        vsteps = s1["spec_verify_steps"] - s0["spec_verify_steps"]
        acc = s1["spec_accepted"] - s0["spec_accepted"]
        drafted = s1["spec_drafted"] - s0["spec_drafted"]
        # committed tokens per verify step per resident row: 1
        # (correction) + accepted drafts
        tok_step = toks / max(1, vsteps) / slots
        # same trace through the host-side n-gram draft (no model) on
        # the warm engine; output identity must survive any proposal
        # stream
        eng.draft = NgramDraft()
        nreqs, _ = serve(eng, 200)
        for b, r in zip(breqs, nreqs):
            assert b.out == r.out, "n-gram output diverged"
        s2 = eng.sched.summary()
        ndraft = s2["spec_drafted"] - s1["spec_drafted"]
        nacc = s2["spec_accepted"] - s1["spec_accepted"]
        # structural: the (B, k) verify graph keeps the serving-graph
        # quantization contract (2 = K/V storage-write amaxes on the
        # fp8 cache; the cache itself is never re-reduced).  Traced
        # abstractly — the pool caches drain to None once the trace
        # retires, so shape the operands from a prefill eval_shape.
        cshape = jax.eval_shape(
            make_prefill_step(cfg, 16, scales=eng.scales,
                              act_scales=eng.act_scales),
            eng.params,
            {"tokens": jax.ShapeDtypeStruct((slots, 12),
                                            jnp.int32)})[1]
        jx = jax.make_jaxpr(make_verify_step(
            cfg, scales=eng.scales, act_scales=eng.act_scales))(
            eng.params, cshape,
            jax.ShapeDtypeStruct((slots, k), jnp.int32))
        row(f"serve_spec_decode_k{k}", dt / toks * 1e6,
            f"tok_s_{toks / dt:.1f}_base_tok_s_{btoks / bdt:.1f}"
            f"_tok_per_step_{tok_step:.2f}"
            f"_accept_rate_{acc / max(1, drafted):.2f}"
            f"_verify_steps_{vsteps}"
            f"_ngram_accept_rate_{nacc / max(1, ndraft):.2f}"
            f"_verify_quant_reductions_{count_quant_reductions(jx)}"
            f"_trace_{n_reqs}reqs_repeated_suffix_max_new_{max_new}")


# ---------------------------------------------------------------------------
# Observability overhead: the SAME trace served all-telemetry-on
# (REPRO_QUANT_HEALTH=1 + span tracing) vs all-off.  The contract
# (docs/observability.md) is that off is FREE — the off-path jaxpr is
# byte-identical, asserted in tests/test_obs.py — and that on costs
# under a few percent of tok/s: the health stats are tiny per-site
# reductions riding existing steps, the spans are host-side
# perf_counter pairs.  CPU wall clock is emulation; overhead_pct is
# the structural column.
# ---------------------------------------------------------------------------


def bench_obs_overhead(arch: str = "phi3-mini-3.8b"):
    from repro.configs.registry import get_config
    from repro.models.layers import init_tree
    from repro.models.transformer import model_defs
    from repro.obs.trace import get_tracer
    from repro.serving import Engine, Request

    cfg = get_config(arch, smoke=True)
    params = init_tree(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_reqs, max_new, slots, max_len = 8, 10, 4, 64
    prompts = [rng.integers(0, cfg.vocab,
                            size=int(rng.integers(8, 24)),
                            dtype=np.int32) for _ in range(n_reqs)]

    def serve(eng, rid0):
        reqs = [Request(rid=rid0 + i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        eng.run(reqs, log=None)
        dt = time.perf_counter() - t0
        eng.prune_finished()
        return sum(len(r.out) for r in reqs), dt, reqs

    stats = {}
    outs = {}
    saved = os.environ.get("REPRO_QUANT_HEALTH")
    tracer = get_tracer()
    for tag in ("off", "on"):
        if tag == "on":
            os.environ["REPRO_QUANT_HEALTH"] = "1"
            tracer.clear()
            tracer.enable()       # ring buffer only, no output path
        else:
            os.environ.pop("REPRO_QUANT_HEALTH", None)
        try:
            eng = Engine(cfg, params, slots, max_len=max_len,
                         prefix_cache=False)
            assert eng.health == (tag == "on")
            serve(eng, 0)                         # warmup (compiles)
            toks, dt, reqs = serve(eng, 100)
            stats[tag] = {"us": dt / toks * 1e6, "tok_s": toks / dt}
            outs[tag] = [r.out for r in reqs]
            if tag == "on":
                s = eng.stats()
                stats[tag]["sites"] = len(s["quant_health"]["sites"])
                stats[tag]["events"] = len(tracer)
        finally:
            if tag == "on":
                tracer.disable()
            (os.environ.pop("REPRO_QUANT_HEALTH", None) if saved is None
             else os.environ.__setitem__("REPRO_QUANT_HEALTH", saved))
    assert outs["on"] == outs["off"], \
        "telemetry changed the greedy output stream"
    on, off = stats["on"], stats["off"]
    overhead = (on["us"] - off["us"]) / off["us"] * 100
    row("serve_obs_overhead", on["us"],
        f"tok_s_on_{on['tok_s']:.1f}_tok_s_off_{off['tok_s']:.1f}"
        f"_overhead_pct_{overhead:.1f}"
        f"_health_sites_{on['sites']}"
        f"_trace_events_{on['events']}"
        f"_trace_{n_reqs}reqs_max_new_{max_new}")


def _write_json(path: str, rows=None) -> None:
    import json

    rows = _ROWS if rows is None else rows
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows to {path}", flush=True)


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced set: dispatch backends + MoE grouped "
                         "A/B + per-mode train-step timings (CI smoke)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as JSON records (machine-"
                         "readable perf trajectory; --smoke defaults "
                         "to BENCH_moe.json)")
    args = ap.parse_args(argv)
    if args.smoke and args.json is None:
        args.json = "BENCH_moe.json"

    print("name,us_per_call,derived")
    if args.smoke:
        bench_dispatch_backends(m=128, n=128, k=512)
        bench_moe_grouped()
        bench_table2_throughput(B=4, S=64, iters=2)
        bench_serve_prequant()
        bench_decode_reduction_free()
        bench_decode_attn()
        bench_serve_continuous()
        bench_serve_prefix()
        bench_serve_slo()
        bench_spec_decode()
        bench_obs_overhead()
        _write_json(args.json)
        # serving / decode-attention rows also land in their own
        # artifacts (consumed by benchmarks/report.py --trajectory
        # alongside BENCH_moe.json)
        _write_json("BENCH_serve.json",
                    [r for r in _ROWS if r["name"].startswith("serve_")])
        _write_json("BENCH_decode.json",
                    [r for r in _ROWS if r["name"].startswith("decode_")])
        return
    bench_table1_autoscale()
    bench_table7_snr()
    bench_dispatch_backends()
    bench_moe_grouped()
    bench_table6_gemm()
    bench_table5_memory_comm()
    bench_table2_throughput()
    bench_table9_interval()
    bench_serve_prequant()
    bench_decode_reduction_free()
    bench_decode_attn()
    bench_serve_continuous()
    bench_serve_prefix()
    bench_serve_slo()
    bench_spec_decode()
    bench_obs_overhead()
    if args.json:
        _write_json(args.json)


if __name__ == "__main__":
    main()
