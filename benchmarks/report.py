"""Inject the §Dry-run and §Roofline tables into EXPERIMENTS.md from the
recorded artifacts.

  PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import analyze, bottleneck_hint  # noqa: E402


def load_records(pattern="experiments/dryrun/*.json",
                 baseline_only=True):
    recs = []
    for path in sorted(glob.glob(pattern)):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        if baseline_only and len(parts) > 3:     # tagged §Perf artifacts
            continue
        recs.append(json.load(open(path)))
    return recs


def dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | status | FLOPs/dev | HLO bytes/dev "
             "| coll wire B/dev | mem/dev GiB | compile s |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r["mesh"])):
        if r["status"] == "ok":
            wire = r["collectives"].get("wire_bytes_per_device", 0)
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r.get('flops_adjusted', r['flops']):.3e} | "
                f"{r.get('bytes_adjusted', r['bytes_accessed']):.3e} | "
                f"{wire:.3e} | "
                f"{r['memory']['total_per_device']/2**30:.2f} | "
                f"{r.get('compile_s', 0)} |")
        elif r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skip | — | — | — | — | — |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | {r.get('error','')[:60]} | | | | |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO flops | roofline frac | "
             "what moves the dominant term |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != "pod16x16":
            continue   # roofline table is single-pod per the spec
        a = analyze(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {a['compute_s']:.4f} | "
            f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
            f"{a['dominant']} | {a['useful_ratio']:.3f} | "
            f"{a['roofline_fraction']:.4f} | {bottleneck_hint(a, r)} |")
    return "\n".join(lines)


def inject(md_path: str, marker: str, table: str):
    text = open(md_path).read()
    pat = re.compile(f"<!-- {marker} -->.*?(?=\n## |\\Z)", re.S)
    repl = f"<!-- {marker} -->\n\n{table}\n"
    if pat.search(text):
        text = pat.sub(repl, text)
    open(md_path, "w").write(text)


def main():
    recs = load_records()
    inject("EXPERIMENTS.md", "DRYRUN_TABLE", dryrun_table(recs))
    inject("EXPERIMENTS.md", "ROOFLINE_TABLE", roofline_table(recs))
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skipped")
    err = sum(1 for r in recs if r["status"] not in ("ok", "skipped"))
    print(f"injected: {ok} ok, {skip} skipped, {err} errors")


if __name__ == "__main__":
    main()
