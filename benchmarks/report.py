"""Render benchmark artifacts into markdown.

Two jobs:

1. Inject the §Dry-run and §Roofline tables into EXPERIMENTS.md from
   the recorded dry-run artifacts (skipped when EXPERIMENTS.md is
   absent).
2. ``--trajectory``: render the per-PR benchmark trajectory table to
   ``docs/bench-trajectory.md`` from the machine-readable
   ``BENCH_*.json`` row files (``benchmarks/run.py --smoke`` writes
   BENCH_moe.json + BENCH_serve.json; CI uploads them per run).
   Committed snapshots live under ``experiments/bench/<label>/`` —
   drop a downloaded CI artifact there to extend the table; loose
   ``./BENCH_*.json`` files from a local run appear as the "local"
   column.

  PYTHONPATH=src python -m benchmarks.report --trajectory
"""

from __future__ import annotations

import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import analyze, bottleneck_hint  # noqa: E402


def load_records(pattern="experiments/dryrun/*.json",
                 baseline_only=True):
    recs = []
    for path in sorted(glob.glob(pattern)):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        if baseline_only and len(parts) > 3:     # tagged §Perf artifacts
            continue
        recs.append(json.load(open(path)))
    return recs


def dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | status | FLOPs/dev | HLO bytes/dev "
             "| coll wire B/dev | mem/dev GiB | compile s |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r["mesh"])):
        if r["status"] == "ok":
            wire = r["collectives"].get("wire_bytes_per_device", 0)
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r.get('flops_adjusted', r['flops']):.3e} | "
                f"{r.get('bytes_adjusted', r['bytes_accessed']):.3e} | "
                f"{wire:.3e} | "
                f"{r['memory']['total_per_device']/2**30:.2f} | "
                f"{r.get('compile_s', 0)} |")
        elif r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skip | — | — | — | — | — |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | {r.get('error','')[:60]} | | | | |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO flops | roofline frac | "
             "what moves the dominant term |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != "pod16x16":
            continue   # roofline table is single-pod per the spec
        a = analyze(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {a['compute_s']:.4f} | "
            f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
            f"{a['dominant']} | {a['useful_ratio']:.3f} | "
            f"{a['roofline_fraction']:.4f} | {bottleneck_hint(a, r)} |")
    return "\n".join(lines)


def inject(md_path: str, marker: str, table: str):
    text = open(md_path).read()
    pat = re.compile(f"<!-- {marker} -->.*?(?=\n## |\\Z)", re.S)
    repl = f"<!-- {marker} -->\n\n{table}\n"
    if pat.search(text):
        text = pat.sub(repl, text)
    open(md_path, "w").write(text)


# ---------------------------------------------------------------------------
# Per-PR benchmark trajectory (ROADMAP "BENCH_moe.json trajectory")
# ---------------------------------------------------------------------------

# name prefixes worth tracking across PRs (exact-name rows first)
TRAJECTORY_PREFIXES = ("moe_grouped_vs_vmapped", "dispatch_",
                       "serve_prequant_", "serve_delayed_",
                       "serve_continuous_", "serve_prefix_",
                       "serve_slo_", "serve_spec_", "serve_obs_",
                       "table2_train_step_", "decode_attn_")

BENCH_PATTERNS = ("experiments/bench/*/BENCH_*.json", "BENCH_*.json")


def load_bench_runs(patterns=BENCH_PATTERNS) -> dict[str, dict]:
    """label -> {row name -> row}.  A label is the artifact's parent
    directory under experiments/bench/ (one per PR / CI run snapshot);
    loose BENCH_*.json in the cwd land under "local"."""
    runs: dict[str, dict] = {}
    for pat in patterns:
        for path in sorted(glob.glob(pat)):
            parent = os.path.basename(os.path.dirname(path))
            label = parent if parent not in ("", ".") else "local"
            for r in json.load(open(path)):
                runs.setdefault(label, {})[r["name"]] = r
    return runs


def _label_key(label: str):
    """Chronological column order: prN snapshots by N (pr3 < pr10), then
    other labels lexicographically, then "local" (the freshest run)."""
    if label == "local":
        return (2, 0, label)
    m = re.match(r"pr(\d+)", label)
    return (0, int(m.group(1)), label) if m else (1, 0, label)


def trajectory_table(runs: dict[str, dict]) -> str:
    labels = sorted(runs, key=_label_key)
    names: list[str] = []
    for label in labels:
        for name in runs[label]:
            if name not in names and any(
                    name.startswith(p) for p in TRAJECTORY_PREFIXES):
                names.append(name)
    lines = ["| bench | " + " | ".join(f"{lb} (µs)" for lb in labels)
             + " | derived (latest) |",
             "|---|" + "---|" * (len(labels) + 1)]
    for name in sorted(names):
        cells, derived = [], ""
        for lb in labels:
            r = runs[lb].get(name)
            cells.append(f"{r['us_per_call']:.1f}" if r else "—")
            if r and r.get("derived"):
                derived = r["derived"]
        lines.append(f"| {name} | " + " | ".join(cells)
                     + f" | {derived} |")
    return "\n".join(lines)


def write_trajectory(out_path: str = "docs/bench-trajectory.md") -> bool:
    runs = load_bench_runs()
    if not runs:
        # the CI docs job runs this to prove the committed page can be
        # regenerated — an empty artifact set means the snapshots under
        # experiments/bench/ went missing, which must FAIL, not no-op
        raise SystemExit("no BENCH_*.json artifacts found (expected "
                         "committed snapshots under experiments/bench/"
                         "<label>/); trajectory not written")
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    body = (
        "# Benchmark trajectory\n\n"
        "Machine-readable rows from `benchmarks/run.py --smoke` "
        "(`BENCH_moe.json`, `BENCH_serve.json`, `BENCH_decode.json`), "
        "one column per snapshot under `experiments/bench/<label>/`.  "
        "Regenerate with:\n\n"
        "```bash\nPYTHONPATH=src python benchmarks/run.py --smoke\n"
        "PYTHONPATH=src python -m benchmarks.report --trajectory\n"
        "```\n\n"
        "Wall clocks are CPU fp8 *emulation* — the structural columns "
        "(launch/amax/cast counts in `derived`) carry the speedup "
        "mechanism; see [serving.md](serving.md) and the kernel notes "
        "in [kernel-contract.md](kernel-contract.md).\n\n"
        + trajectory_table(runs) + "\n")
    open(out_path, "w").write(body)
    print(f"wrote {out_path} ({len(runs)} snapshot(s))")
    return True


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trajectory", action="store_true",
                    help="render docs/bench-trajectory.md from "
                         "BENCH_*.json artifacts")
    args = ap.parse_args(argv)
    if args.trajectory:
        write_trajectory()
        return
    if not os.path.exists("EXPERIMENTS.md"):
        print("EXPERIMENTS.md not present; nothing to inject "
              "(use --trajectory for docs/bench-trajectory.md)")
        return
    recs = load_records()
    inject("EXPERIMENTS.md", "DRYRUN_TABLE", dryrun_table(recs))
    inject("EXPERIMENTS.md", "ROOFLINE_TABLE", roofline_table(recs))
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skipped")
    err = sum(1 for r in recs if r["status"] not in ("ok", "skipped"))
    print(f"injected: {ok} ok, {skip} skipped, {err} errors")


if __name__ == "__main__":
    main()
