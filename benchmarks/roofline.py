"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md
§Roofline).

Per (arch × shape × mesh) cell, from experiments/dryrun/*.json:
  compute term    = HLO_FLOPs_per_device            / PEAK_FLOPS
  memory term     = HLO_bytes_per_device            / HBM_BW
  collective term = collective_wire_bytes_per_device / LINK_BW

HLO_FLOPs/bytes are the while-trip-adjusted per-device numbers from the
dry-run (cost_analysis on an SPMD module is per device; scan bodies are
re-multiplied via the per-segment probes — see launch/dryrun.py).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens per step;
serve steps use 2·N(+attention) per token forward-only accounting.
"""

from __future__ import annotations

import glob
import json
import os
import sys

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s/link (ICI)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def param_count(cfg) -> dict:
    """Analytic parameter counts (total and activated-per-token)."""
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    embed = v * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.kv_lora:   # MLA
            dq = cfg.q_nope + cfg.q_rope
            return (d * cfg.n_heads * dq + d * cfg.kv_lora + d * cfg.q_rope
                    + cfg.kv_lora * cfg.n_heads * (cfg.q_nope + cfg.v_head)
                    + cfg.n_heads * cfg.v_head * d)
        dh = cfg.head_dim
        return d * dh * (cfg.n_heads * 2 + cfg.n_kv * 2)

    def ffn_params(width):
        gated = cfg.act in ("swiglu", "geglu")
        return d * width * (3 if gated else 2)

    if cfg.family == "ssm":
        per_layer = 5 * d * d + 2 * d * cfg.d_ff + d * d \
            + d * (5 * cfg.ddlerp_rank) + cfg.decay_rank * 2 * d
        total = embed + L * per_layer
        return {"total": total, "active": total}
    if cfg.family == "hybrid":
        lru = cfg.lru_width
        rec = 2 * d * lru + lru * d + 2 * lru * lru + ffn_params(f)
        att = attn_params() + ffn_params(f)
        n_att = L // 3
        total = embed + (L - n_att) * rec + n_att * att
        return {"total": total, "active": total}
    per_layer_dense = attn_params() + ffn_params(cfg.dense_ff or f)
    if cfg.n_experts:
        expert = ffn_params(f)
        moe_layers = L - cfg.first_dense
        total = embed + cfg.first_dense * per_layer_dense + moe_layers * (
            attn_params() + cfg.n_experts * expert
            + cfg.n_shared * expert + d * cfg.n_experts)
        active = embed + cfg.first_dense * per_layer_dense + moe_layers * (
            attn_params() + (cfg.top_k + cfg.n_shared) * expert)
        return {"total": total, "active": active}
    total = embed + L * per_layer_dense
    return {"total": total, "active": total}


def model_flops(cfg, shape) -> float:
    """6·N_active·D for training; 2·N_active·tokens for serving steps."""
    pc = param_count(cfg)
    n = pc["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: 1 new token


def analyze(record: dict) -> dict:
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config

    cfg = get_config(record["arch"])
    shape = SHAPES[record["shape"]]
    chips = record["n_devices"]

    flops_dev = record.get("flops_adjusted", record["flops"])
    bytes_dev = record.get("bytes_adjusted", record["bytes_accessed"])
    wire_dev = record["collectives"].get(
        "wire_bytes_per_device", record["collectives"]["total_bytes"])

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = wire_dev / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)

    mflops = model_flops(cfg, shape)
    hlo_total = flops_dev * chips
    useful = mflops / hlo_total if hlo_total else 0.0
    # roofline fraction: useful model flops per second at the bound set
    # by the dominant term, relative to the chips' peak
    step_time = max(terms.values())
    achieved = mflops / step_time / chips if step_time else 0.0
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mflops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": round(useful, 4),
        "roofline_fraction": round(achieved / PEAK_FLOPS, 4),
        "step_time_s": round(step_time, 6),
    }


def bottleneck_hint(analysis: dict, record: dict) -> str:
    d = analysis["dominant"]
    if d == "collective":
        return ("shrink TP traffic: move activation sharding off the "
                "model axis (FSDP-dominant layout) or overlap the "
                "per-layer all-reduces with the next GEMM")
    if d == "memory":
        return ("cut HBM bytes: fp8 weight/KV-cache storage and larger "
                "fused blocks (fewer accumulator spills)")
    return ("raise MXU utilization: bigger per-chip GEMM tiles "
            "(less padding), or drop remat recompute on the cheap ops")


def kv_cache_traffic(cfg, shape) -> dict | None:
    """Analytic per-decode-step KV-cache HBM read, bf16 vs fp8 storage
    (the serving default — configs/base.py ``kv_cache_dtype``).

    Every decode step reads the whole valid cache: K and V payloads of
    ``C = min(seq, window)`` positions × n_kv heads × head_dim per
    layer, plus (fp8 only) the per-(token, kv-head) f32 scales.  The
    ratio is the structural HBM-traffic claim of the fp8 cache:
    2 / (1 + 4/head_dim) ≈ 2× for the assigned head dims.

    Returns None for archs without a per-head KV cache (SSM states;
    MLA's absorbed latent cache is already compressed and stays bf16).
    """
    if cfg.family == "ssm" or cfg.kv_lora:
        return None
    n_attn = cfg.n_layers // 3 if cfg.family == "hybrid" else cfg.n_layers
    c = min(shape.seq_len, cfg.window) if (cfg.attn_type in ("swa", "local")
                                           or cfg.family == "hybrid") \
        else shape.seq_len
    elems = 2 * shape.global_batch * c * cfg.n_kv * cfg.head_dim  # K and V
    scales = 2 * shape.global_batch * c * cfg.n_kv                # fp8 only
    bytes_bf16 = 2 * elems * n_attn
    bytes_fp8 = (elems + 4 * scales) * n_attn
    return {"kv_bytes_bf16": bytes_bf16, "kv_bytes_fp8": bytes_fp8,
            "kv_read_ratio": round(bytes_bf16 / bytes_fp8, 3),
            "kv_s_bf16": round(bytes_bf16 / HBM_BW, 6),
            "kv_s_fp8": round(bytes_fp8 / HBM_BW, 6)}


def kv_traffic_rows() -> list[dict]:
    """One fp8-vs-bf16 KV HBM-traffic row per decode-bound cell —
    structural (config-derived), needs no dry-run artifacts."""
    from repro.configs.base import SHAPES, shape_applicable
    from repro.configs.registry import ASSIGNED, get_config

    rows = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.kind != "decode" or not shape_applicable(cfg, shape)[0]:
                continue
            t = kv_cache_traffic(cfg, shape)
            if t is None:
                continue
            rows.append({"arch": arch, "shape": shape.name, **t})
    return rows


def print_kv_traffic(rows: list[dict]) -> None:
    hdr = (f"{'arch':25s} {'shape':12s} {'KV bf16 B/step':>15s} "
           f"{'KV fp8 B/step':>14s} {'ratio':>6s} {'mem(s) bf16':>12s} "
           f"{'mem(s) fp8':>11s}")
    print("\n# fp8 KV cache: per-decode-step HBM read (serving default "
          "vs kv_cache_dtype=\"bf16\")")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:25s} {r['shape']:12s} "
              f"{r['kv_bytes_bf16']:15.3e} {r['kv_bytes_fp8']:14.3e} "
              f"{r['kv_read_ratio']:6.2f} {r['kv_s_bf16']:12.4f} "
              f"{r['kv_s_fp8']:11.4f}")


def main(out_path: str | None = None):
    rows = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"],
                         "status": rec.get("status"),
                         "reason": rec.get("reason",
                                           rec.get("error", ""))[:90]})
            continue
        a = analyze(rec)
        rows.append({"arch": rec["arch"], "shape": rec["shape"],
                     "mesh": rec["mesh"], "status": "ok", **a,
                     "hint": bottleneck_hint(a, rec)})

    hdr = (f"{'arch':25s} {'shape':12s} {'mesh':11s} {'comp(s)':>9s} "
           f"{'mem(s)':>9s} {'coll(s)':>9s} {'dom':>6s} {'useful':>7s} "
           f"{'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"{r['arch']:25s} {r['shape']:12s} "
                         f"{r['mesh']:11s} {r['status']}: "
                         f"{r.get('reason','')}")
            continue
        lines.append(
            f"{r['arch']:25s} {r['shape']:12s} {r['mesh']:11s} "
            f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
            f"{r['collective_s']:9.4f} {r['dominant']:>6s} "
            f"{r['useful_ratio']:7.3f} {r['roofline_fraction']:9.4f}")
    table = "\n".join(lines)
    print(table)
    kv_rows = kv_traffic_rows()
    print_kv_traffic(kv_rows)
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump({"cells": rows, "kv_traffic": kv_rows}, f, indent=1)
    return rows


if __name__ == "__main__":
    main(out_path="experiments/roofline.json")
